//! The Linker (paper Fig. 5 component 5, Fig. 7 mechanism).
//!
//! "Linker links the KV cache of multimodal information to users' queries."
//! Concretely: given a [`LinkedLayout`], the fetched per-segment KV entries
//! (images *and* cached text chunks) and a [`SelectionPlan`], it assembles
//! the activation tensors of the AOT artifacts — the linked
//! (position-stale) K/V cache with zero-filled *dummy* rows for selected
//! tokens, the per-slot position/validity/sink vectors, and the packed
//! selection arrays.
//!
//! This is L3's hot path; the performance pass (EXPERIMENTS.md §Perf)
//! tracks its assembly time separately from device execution.

use anyhow::{bail, ensure};

use super::selection::SelectionPlan;
use crate::kv::SegmentKv;
use crate::mm::{LinkedLayout, SegmentId, TokenKind};
use crate::runtime::{ModelMeta, Tensor};
use crate::Result;

/// Linked position used for padding slots (matches `python/tests` usage).
pub const PAD_POS: i32 = 1_000_000;

/// Per-slot metadata shared by every artifact operating on a bucket.
#[derive(Debug, Clone)]
pub struct SlotArrays {
    pub key_pos: Vec<i32>,
    pub key_valid: Vec<f32>,
    pub sink_bias: Vec<f32>,
}

impl SlotArrays {
    pub fn build(layout: &LinkedLayout, meta: &ModelMeta, bucket: usize) -> SlotArrays {
        let len = layout.len();
        let mut key_pos = vec![PAD_POS; bucket];
        let mut key_valid = vec![0f32; bucket];
        for (i, kp) in key_pos.iter_mut().enumerate().take(len.min(bucket)) {
            *kp = i as i32;
            key_valid[i] = 1.0;
        }
        let kinds = layout.kinds(bucket);
        let rel = layout.img_rel(bucket);
        let sink_bias = crate::mm::make_sink_bias(meta.sink_params(), &kinds, &rel);
        SlotArrays { key_pos, key_valid, sink_bias }
    }

    pub fn tensors(&self) -> (Tensor, Tensor, Tensor) {
        (
            Tensor::i32(vec![self.key_pos.len()], self.key_pos.clone()),
            Tensor::f32(vec![self.key_valid.len()], self.key_valid.clone()),
            Tensor::f32(vec![self.sink_bias.len()], self.sink_bias.clone()),
        )
    }
}

/// Activation set for `prefill_full` / `prefill_debug` / `layer0_k`.
#[derive(Debug, Clone)]
pub struct FullPrefillInputs {
    pub ids: Tensor,
    pub img_emb: Tensor,
    pub is_img: Tensor,
    pub positions: Tensor,
    pub valid: Tensor,
    pub sink_bias: Tensor,
    pub last_idx: Tensor,
    pub bucket: usize,
}

impl FullPrefillInputs {
    pub fn to_vec(&self) -> Vec<Tensor> {
        vec![
            self.ids.clone(),
            self.img_emb.clone(),
            self.is_img.clone(),
            self.positions.clone(),
            self.valid.clone(),
            self.sink_bias.clone(),
            self.last_idx.clone(),
        ]
    }

    /// The subset used by `layer0_k` (ids, img_emb, is_img, positions).
    pub fn layer0_vec(&self) -> Vec<Tensor> {
        vec![self.ids.clone(), self.img_emb.clone(), self.is_img.clone(), self.positions.clone()]
    }
}

/// Activation set for `prefill_selective`.
#[derive(Debug, Clone)]
pub struct SelectiveInputs {
    pub sel_ids: Tensor,
    pub sel_img_emb: Tensor,
    pub sel_is_img: Tensor,
    pub sel_pos: Tensor,
    pub sel_slot: Tensor,
    pub last_sel: Tensor,
    pub k_cache: Tensor,
    pub v_cache: Tensor,
    pub key_pos: Tensor,
    pub key_valid: Tensor,
    pub sink_bias: Tensor,
    pub s_bucket: usize,
    pub n_bucket: usize,
    /// Number of real (non-padding) selected tokens.
    pub n_selected: usize,
}

impl SelectiveInputs {
    pub fn to_vec(self) -> Vec<Tensor> {
        vec![
            self.sel_ids,
            self.sel_img_emb,
            self.sel_is_img,
            self.sel_pos,
            self.sel_slot,
            self.last_sel,
            self.k_cache,
            self.v_cache,
            self.key_pos,
            self.key_valid,
            self.sink_bias,
        ]
    }
}

/// The linker. Stateless; methods are pure assembly.
pub struct Linker<'a> {
    pub meta: &'a ModelMeta,
}

impl<'a> Linker<'a> {
    pub fn new(meta: &'a ModelMeta) -> Linker<'a> {
        Linker { meta }
    }

    /// Fetch entry lookup: `entries[i]` corresponds to
    /// `layout.reuse_spans[i]`. Duplicate spans may share one `Arc`d
    /// entry; only identity and shape are checked here.
    fn check_entries(&self, layout: &LinkedLayout, entries: &[&SegmentKv]) -> Result<()> {
        ensure!(
            entries.len() == layout.reuse_spans.len(),
            "linker: {} KV entries for {} reuse spans",
            entries.len(),
            layout.reuse_spans.len()
        );
        for (e, span) in entries.iter().zip(&layout.reuse_spans) {
            ensure!(e.key.seg == span.seg, "linker: entry/span segment mismatch");
            ensure!(
                e.shape.tokens == span.len(),
                "linker: segment {:?} has {} stored tokens but span is {}",
                span.seg,
                e.shape.tokens,
                span.len()
            );
            ensure!(e.shape.layers == self.meta.n_layers, "layer count mismatch");
            ensure!(e.shape.heads == self.meta.n_heads, "head count mismatch");
            ensure!(e.shape.d_head == self.meta.d_head, "head dim mismatch");
            if matches!(span.seg, SegmentId::Image(_)) {
                // Only image entries carry embeddings the linker reads.
                ensure!(e.shape.d_model == self.meta.d_model, "model dim mismatch");
                ensure!(
                    e.emb.len() == e.shape.emb_elems(),
                    "image entry without embeddings"
                );
            }
        }
        Ok(())
    }

    /// Assemble `prefill_full` inputs (prefix caching, text-only step of the
    /// two-step algorithms when given a text-only layout, debug analysis).
    /// Chunk tokens enter as ordinary text tokens (their vocab ids are in
    /// the layout) — prefix caching recomputes them exactly.
    pub fn full_prefill(
        &self,
        layout: &LinkedLayout,
        entries: &[&SegmentKv],
        bucket: usize,
    ) -> Result<FullPrefillInputs> {
        self.check_entries(layout, entries)?;
        let len = layout.len();
        ensure!(len <= bucket, "layout of {len} tokens exceeds bucket {bucket}");
        ensure!(len >= 1, "empty layout");

        let d = self.meta.d_model;
        let mut ids = vec![0i32; bucket];
        let mut img_emb = vec![0f32; bucket * d];
        let mut is_img = vec![0f32; bucket];
        let mut positions = vec![PAD_POS; bucket];
        let mut valid = vec![0f32; bucket];

        for (i, tok) in layout.tokens.iter().enumerate() {
            positions[i] = i as i32;
            valid[i] = 1.0;
            match tok {
                TokenKind::Text(id) => ids[i] = *id,
                TokenKind::Chunk { tok, .. } => ids[i] = *tok,
                TokenKind::Image { .. } => {}
            }
        }
        for (span_idx, span) in layout.reuse_spans.iter().enumerate() {
            if !matches!(span.seg, SegmentId::Image(_)) {
                continue;
            }
            let e = entries[span_idx];
            for (rel, slot) in (span.lo..span.hi).enumerate() {
                is_img[slot] = 1.0;
                img_emb[slot * d..(slot + 1) * d]
                    .copy_from_slice(&e.emb[rel * d..(rel + 1) * d]);
            }
        }

        let slots = SlotArrays::build(layout, self.meta, bucket);
        Ok(FullPrefillInputs {
            ids: Tensor::i32(vec![bucket], ids),
            img_emb: Tensor::f32(vec![bucket, d], img_emb),
            is_img: Tensor::f32(vec![bucket], is_img),
            positions: Tensor::i32(vec![bucket], positions),
            valid: Tensor::f32(vec![bucket], valid),
            sink_bias: Tensor::f32(vec![bucket], slots.sink_bias),
            last_idx: Tensor::scalar_i32(len as i32 - 1),
            bucket,
        })
    }

    /// Build a *text-only* compacted layout for the two-step baselines'
    /// step A: free-text tokens keep their **linked** positions but are
    /// packed into the low slots of a (smaller) bucket. Chunk tokens are
    /// NOT included — their KV is reused, not recomputed.
    ///
    /// Returns the `prefill_full` inputs plus the mapping from packed index
    /// to original linked slot.
    pub fn text_only_prefill(
        &self,
        layout: &LinkedLayout,
        bucket: usize,
    ) -> Result<(FullPrefillInputs, Vec<usize>)> {
        let text_idx = layout.text_indices();
        let n = text_idx.len();
        ensure!(n >= 1, "no text tokens");
        ensure!(n <= bucket, "text of {n} tokens exceeds bucket {bucket}");
        let d = self.meta.d_model;

        let mut ids = vec![0i32; bucket];
        let img_emb = vec![0f32; bucket * d];
        let is_img = vec![0f32; bucket];
        let mut positions = vec![PAD_POS; bucket];
        let mut valid = vec![0f32; bucket];
        let mut kinds = vec![0u8; bucket];
        for (packed, &slot) in text_idx.iter().enumerate() {
            if let TokenKind::Text(id) = layout.tokens[slot] {
                ids[packed] = id;
            }
            positions[packed] = slot as i32;
            valid[packed] = 1.0;
            kinds[packed] = 1;
        }
        let rel = vec![0u32; bucket];
        let sink_bias = crate::mm::make_sink_bias(self.meta.sink_params(), &kinds, &rel);

        Ok((
            FullPrefillInputs {
                ids: Tensor::i32(vec![bucket], ids),
                img_emb: Tensor::f32(vec![bucket, d], img_emb),
                is_img: Tensor::f32(vec![bucket], is_img),
                positions: Tensor::i32(vec![bucket], positions),
                valid: Tensor::f32(vec![bucket], valid),
                sink_bias: Tensor::f32(vec![bucket], sink_bias),
                last_idx: Tensor::scalar_i32(n as i32 - 1),
                bucket,
            },
            text_idx,
        ))
    }

    /// Scatter stored segment KV entries into a zeroed linked cache
    /// `[L, S, H, Dh]` (the dummy cache of §5.1: free-text rows stay zero).
    /// Image and chunk rows are spliced identically — both were computed
    /// at canonical positions `0..n` and are position-stale here.
    pub fn linked_cache(
        &self,
        layout: &LinkedLayout,
        entries: &[&SegmentKv],
        bucket: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.check_entries(layout, entries)?;
        let (l, h, dh) = (self.meta.n_layers, self.meta.n_heads, self.meta.d_head);
        let row = h * dh;
        let mut k = vec![0f32; l * bucket * row];
        let mut v = vec![0f32; l * bucket * row];
        for (span_idx, span) in layout.reuse_spans.iter().enumerate() {
            let e = entries[span_idx];
            let t = span.len();
            for layer in 0..l {
                let src_base = layer * t * row;
                let dst_base = layer * bucket * row + span.lo * row;
                k[dst_base..dst_base + t * row]
                    .copy_from_slice(&e.k[src_base..src_base + t * row]);
                v[dst_base..dst_base + t * row]
                    .copy_from_slice(&e.v[src_base..src_base + t * row]);
            }
        }
        Ok((k, v))
    }

    /// A zeroed linked cache pair `[L, S, H, Dh]` for incremental
    /// assembly via [`Linker::scatter_group`] (the streamed-fetch path;
    /// [`Linker::linked_cache`] is the one-shot equivalent).
    pub fn empty_linked_cache(&self, bucket: usize) -> (Vec<f32>, Vec<f32>) {
        let n = self.meta.n_layers * bucket * self.meta.n_heads * self.meta.d_head;
        (vec![0f32; n], vec![0f32; n])
    }

    /// Scatter one span's K/V rows for the layer range `[layer_lo,
    /// layer_hi)` into a linked cache. `group_k`/`group_v` are
    /// layer-major `[(layer_hi − layer_lo), T, H, Dh]` — exactly a
    /// `codec::GroupPayload`'s `k`/`v`, or a slice of a whole entry's
    /// vectors. Layers outside the range are untouched, so a streamed
    /// fetch can splice groups as they inflate while deeper groups are
    /// still loading.
    #[allow(clippy::too_many_arguments)]
    pub fn scatter_group(
        &self,
        k_cache: &mut [f32],
        v_cache: &mut [f32],
        bucket: usize,
        span: &crate::mm::ReuseSpan,
        group_k: &[f32],
        group_v: &[f32],
        layer_lo: usize,
        layer_hi: usize,
    ) -> Result<()> {
        let (l, h, dh) = (self.meta.n_layers, self.meta.n_heads, self.meta.d_head);
        let row = h * dh;
        let t = span.len();
        ensure!(
            layer_lo < layer_hi && layer_hi <= l,
            "layer range [{layer_lo}, {layer_hi}) out of 0..{l}"
        );
        ensure!(span.hi <= bucket, "span {}..{} exceeds bucket {bucket}", span.lo, span.hi);
        ensure!(k_cache.len() == l * bucket * row, "k_cache size mismatch");
        ensure!(v_cache.len() == l * bucket * row, "v_cache size mismatch");
        let want = (layer_hi - layer_lo) * t * row;
        ensure!(group_k.len() == want && group_v.len() == want, "group payload size mismatch");
        for layer in layer_lo..layer_hi {
            let src_base = (layer - layer_lo) * t * row;
            let dst_base = layer * bucket * row + span.lo * row;
            k_cache[dst_base..dst_base + t * row]
                .copy_from_slice(&group_k[src_base..src_base + t * row]);
            v_cache[dst_base..dst_base + t * row]
                .copy_from_slice(&group_v[src_base..src_base + t * row]);
        }
        Ok(())
    }

    /// Overwrite rows of a linked cache with freshly computed rows coming
    /// from a *packed* prefill output (`text_only_prefill` step A):
    /// `packed_kv` is `[L, S_packed, H, Dh]`, `mapping[packed] = slot`.
    pub fn scatter_packed_rows(
        &self,
        cache: &mut [f32],
        bucket: usize,
        packed_kv: &[f32],
        packed_bucket: usize,
        mapping: &[usize],
    ) -> Result<()> {
        let (l, h, dh) = (self.meta.n_layers, self.meta.n_heads, self.meta.d_head);
        let row = h * dh;
        ensure!(cache.len() == l * bucket * row, "cache size mismatch");
        ensure!(packed_kv.len() == l * packed_bucket * row, "packed size mismatch");
        for layer in 0..l {
            for (packed, &slot) in mapping.iter().enumerate() {
                if slot >= bucket {
                    bail!("mapping slot {slot} out of bucket {bucket}");
                }
                let src = layer * packed_bucket * row + packed * row;
                let dst = layer * bucket * row + slot * row;
                cache[dst..dst + row].copy_from_slice(&packed_kv[src..src + row]);
            }
        }
        Ok(())
    }

    /// Assemble `prefill_selective` inputs for a selection plan.
    ///
    /// `k_cache`/`v_cache` are the linked cache (usually from
    /// [`Linker::linked_cache`], possibly with text rows scattered in for
    /// the CacheBlend path). Selected image tokens need their encoder
    /// embedding (from the entry); selected chunk tokens re-enter by
    /// vocab id, like text.
    #[allow(clippy::too_many_arguments)]
    pub fn selective(
        &self,
        layout: &LinkedLayout,
        entries: &[&SegmentKv],
        plan: &SelectionPlan,
        k_cache: Vec<f32>,
        v_cache: Vec<f32>,
        s_bucket: usize,
        n_bucket: usize,
    ) -> Result<SelectiveInputs> {
        self.check_entries(layout, entries)?;
        let n_sel = plan.selected.len();
        ensure!(n_sel >= 1, "selective pass needs at least one selected token");
        ensure!(n_sel <= n_bucket, "{n_sel} selected tokens exceed N bucket {n_bucket}");
        ensure!(layout.len() <= s_bucket, "layout exceeds S bucket");
        let d = self.meta.d_model;
        let row = self.meta.n_heads * self.meta.d_head;
        ensure!(k_cache.len() == self.meta.n_layers * s_bucket * row, "k_cache size");
        ensure!(v_cache.len() == k_cache.len(), "v_cache size");

        // Span lookup for image-token embeddings.
        let span_of_slot = |slot: usize| -> Option<(usize, usize)> {
            layout
                .reuse_spans
                .iter()
                .enumerate()
                .find(|(_, span)| slot >= span.lo && slot < span.hi)
                .map(|(idx, span)| (idx, slot - span.lo))
        };

        let mut sel_ids = vec![0i32; n_bucket];
        let mut sel_img_emb = vec![0f32; n_bucket * d];
        let mut sel_is_img = vec![0f32; n_bucket];
        // Padding queries sit at position 0 (attend ~nothing) and scatter to
        // slot S+1, which the jnp `mode="drop"` scatter discards.
        let mut sel_pos = vec![0i32; n_bucket];
        let mut sel_slot = vec![s_bucket as i32 + 1; n_bucket];

        let mut last_sel = 0usize;
        let mut last_pos = -1i64;
        for (i, &slot) in plan.selected.iter().enumerate() {
            ensure!(slot < layout.len(), "selected slot {slot} out of range");
            sel_pos[i] = slot as i32;
            sel_slot[i] = slot as i32;
            match layout.tokens[slot] {
                TokenKind::Text(id) => sel_ids[i] = id,
                TokenKind::Chunk { tok, .. } => sel_ids[i] = tok,
                TokenKind::Image { .. } => {
                    let (span_idx, rel) = span_of_slot(slot)
                        .ok_or_else(|| anyhow::anyhow!("image token outside any span"))?;
                    sel_is_img[i] = 1.0;
                    let e = entries[span_idx];
                    sel_img_emb[i * d..(i + 1) * d]
                        .copy_from_slice(&e.emb[rel * d..(rel + 1) * d]);
                }
            }
            if slot as i64 > last_pos {
                last_pos = slot as i64;
                last_sel = i;
            }
        }
        ensure!(
            last_pos == layout.len() as i64 - 1,
            "the final prompt token must be selected (got last selected pos {last_pos})"
        );

        let slots = SlotArrays::build(layout, self.meta, s_bucket);
        Ok(SelectiveInputs {
            sel_ids: Tensor::i32(vec![n_bucket], sel_ids),
            sel_img_emb: Tensor::f32(vec![n_bucket, d], sel_img_emb),
            sel_is_img: Tensor::f32(vec![n_bucket], sel_is_img),
            sel_pos: Tensor::i32(vec![n_bucket], sel_pos),
            sel_slot: Tensor::i32(vec![n_bucket], sel_slot),
            last_sel: Tensor::scalar_i32(last_sel as i32),
            k_cache: Tensor::f32(
                vec![self.meta.n_layers, s_bucket, self.meta.n_heads, self.meta.d_head],
                k_cache,
            ),
            v_cache: Tensor::f32(
                vec![self.meta.n_layers, s_bucket, self.meta.n_heads, self.meta.d_head],
                v_cache,
            ),
            key_pos: Tensor::i32(vec![s_bucket], slots.key_pos),
            key_valid: Tensor::f32(vec![s_bucket], slots.key_valid),
            sink_bias: Tensor::f32(vec![s_bucket], slots.sink_bias),
            s_bucket,
            n_bucket,
            n_selected: n_sel,
        })
    }

    /// Per-reused-token layer-0 K deviation: |stored - recomputed| L1 over
    /// heads×dims, for CacheBlend's selector. `k0_linked` is the
    /// `layer0_k` output `[S, H, Dh]` at linked positions. Image and
    /// chunk spans both contribute (their stored rows are equally
    /// position-stale).
    pub fn layer0_deviation(
        &self,
        layout: &LinkedLayout,
        entries: &[&SegmentKv],
        k0_linked: &[f32],
        bucket: usize,
    ) -> Result<Vec<f32>> {
        self.check_entries(layout, entries)?;
        let row = self.meta.n_heads * self.meta.d_head;
        ensure!(k0_linked.len() == bucket * row, "k0 size mismatch");
        let mut dev = vec![0f32; layout.len()];
        for (span_idx, span) in layout.reuse_spans.iter().enumerate() {
            let e = entries[span_idx];
            // Stored layer-0 K rows: e.k layout [L, T, H, Dh], layer 0 first.
            for (rel, slot) in (span.lo..span.hi).enumerate() {
                let stored = &e.k[rel * row..(rel + 1) * row];
                let fresh = &k0_linked[slot * row..(slot + 1) * row];
                dev[slot] = stored.iter().zip(fresh).map(|(a, b)| (a - b).abs()).sum();
            }
        }
        Ok(dev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::selection::{plan, Policy};
    use crate::kv::{KvKey, KvShape};
    use crate::mm::{ChunkId, ChunkRef, ImageId, Prompt, Tokenizer, UserId};
    use crate::runtime::artifacts::WeightsMeta;

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "test-model".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            d_head: 4,
            d_ff: 16,
            vocab: 4096,
            img_tokens: 4,
            patch_dim: 4,
            rope_theta: 1e4,
            sink_sigma: 3.0,
            sink_tau: 8.0,
            bos_bias: 2.0,
            weights: WeightsMeta {
                file: "none".into(),
                total_bytes: 0,
                sha256: String::new(),
                tensors: vec![],
            },
        }
    }

    fn entry(meta: &ModelMeta, image: u64, marker: f32) -> SegmentKv {
        let shape = KvShape {
            layers: meta.n_layers,
            tokens: meta.img_tokens,
            heads: meta.n_heads,
            d_head: meta.d_head,
            d_model: meta.d_model,
        };
        SegmentKv {
            key: KvKey::image(&meta.name, ImageId(image)),
            shape,
            emb: vec![marker; shape.emb_elems()],
            k: (0..shape.kv_elems()).map(|i| marker + i as f32 * 1e-3).collect(),
            v: (0..shape.kv_elems()).map(|i| -marker - i as f32 * 1e-3).collect(),
        }
    }

    fn chunk_entry(meta: &ModelMeta, chunk: u64, tokens: usize, marker: f32) -> SegmentKv {
        let shape = KvShape {
            layers: meta.n_layers,
            tokens,
            heads: meta.n_heads,
            d_head: meta.d_head,
            d_model: meta.d_model,
        };
        SegmentKv {
            key: KvKey::chunk(&meta.name, ChunkId(chunk)),
            shape,
            emb: Vec::new(),
            k: (0..shape.kv_elems()).map(|i| marker + i as f32 * 1e-3).collect(),
            v: (0..shape.kv_elems()).map(|i| -marker - i as f32 * 1e-3).collect(),
        }
    }

    fn fixture() -> (ModelMeta, LinkedLayout, SegmentKv, SegmentKv) {
        let m = meta();
        let t = Tokenizer::new(4096);
        let p = Prompt::new(UserId(1))
            .text("look here")
            .image(ImageId(1))
            .text("and")
            .image(ImageId(2))
            .text("compare");
        let l = LinkedLayout::build(&p, &t, m.img_tokens, "sys");
        let e1 = entry(&m, 1, 1.0);
        let e2 = entry(&m, 2, 2.0);
        (m, l, e1, e2)
    }

    /// Fixture with a chunk span between text and an image span.
    fn chunk_fixture() -> (ModelMeta, LinkedLayout, SegmentKv, SegmentKv, Vec<i32>) {
        let m = meta();
        let t = Tokenizer::new(4096);
        let doc_tokens = t.encode("harbour festival report with five words more");
        let p = Prompt::new(UserId(1))
            .text("context")
            .chunk(ChunkRef::resolved(ChunkId(7), doc_tokens.clone()))
            .image(ImageId(1))
            .text("question");
        let l = LinkedLayout::build(&p, &t, m.img_tokens, "sys");
        let ce = chunk_entry(&m, 7, doc_tokens.len(), 5.0);
        let ie = entry(&m, 1, 1.0);
        (m, l, ce, ie, doc_tokens)
    }

    #[test]
    fn full_prefill_layout() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let inputs = linker.full_prefill(&l, &[&e1, &e2], 32).unwrap();
        let is_img = inputs.is_img.f32_data().unwrap();
        let span1 = l.reuse_spans[0];
        assert!(is_img[span1.lo..span1.hi].iter().all(|&x| x == 1.0));
        assert_eq!(is_img.iter().filter(|&&x| x == 1.0).count(), 8);
        // Image embeddings marked per entry.
        let emb = inputs.img_emb.f32_data().unwrap();
        assert_eq!(emb[span1.lo * m.d_model], 1.0);
        let span2 = l.reuse_spans[1];
        assert_eq!(emb[span2.lo * m.d_model], 2.0);
        // Positions: arange then PAD.
        let pos = inputs.positions.i32_data().unwrap();
        assert_eq!(pos[0], 0);
        assert_eq!(pos[l.len() - 1], l.len() as i32 - 1);
        assert_eq!(pos[l.len()], PAD_POS);
        assert_eq!(inputs.last_idx.i32_data().unwrap()[0], l.len() as i32 - 1);
    }

    #[test]
    fn full_prefill_feeds_chunk_tokens_as_ids() {
        let (m, l, ce, ie, doc_tokens) = chunk_fixture();
        let linker = Linker::new(&m);
        let inputs = linker.full_prefill(&l, &[&ce, &ie], 64).unwrap();
        let ids = inputs.ids.i32_data().unwrap();
        let is_img = inputs.is_img.f32_data().unwrap();
        let chunk_span = l.reuse_spans[0];
        for (rel, slot) in (chunk_span.lo..chunk_span.hi).enumerate() {
            assert_eq!(ids[slot], doc_tokens[rel], "chunk slot {slot} must carry its vocab id");
            assert_eq!(is_img[slot], 0.0, "chunk tokens are not image tokens");
        }
        // The image span still contributes embeddings.
        let img_span = l.reuse_spans[1];
        assert!(is_img[img_span.lo..img_span.hi].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn linked_cache_scatters_rows() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let bucket = 32;
        let (k, _v) = linker.linked_cache(&l, &[&e1, &e2], bucket).unwrap();
        let row = m.n_heads * m.d_head;
        let span1 = l.reuse_spans[0];
        // Layer 0, first image, rel 0 == stored k[0..row].
        let dst = span1.lo * row;
        assert_eq!(&k[dst..dst + row], &e1.k[0..row]);
        // Layer 1 row of image 2, rel 1.
        let span2 = l.reuse_spans[1];
        let dst = bucket * row + (span2.lo + 1) * row; // layer 1 base + slot
        let src = m.img_tokens * row + row; // layer 1 base + rel 1
        assert_eq!(&k[dst..dst + row], &e2.k[src..src + row]);
        // Text slots are dummy zeros.
        let text_slot = l.text_indices()[0];
        assert!(k[text_slot * row..(text_slot + 1) * row].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scatter_group_layerwise_matches_one_shot_linked_cache() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let bucket = 32;
        let (k_ref, v_ref) = linker.linked_cache(&l, &[&e1, &e2], bucket).unwrap();

        // Rebuild the same cache one layer at a time per span, the way a
        // streamed fetch splices groups as they inflate.
        let (mut k, mut v) = linker.empty_linked_cache(bucket);
        let row = m.n_heads * m.d_head;
        for (span, e) in l.reuse_spans.iter().zip([&e1, &e2]) {
            let t = span.len();
            for layer in 0..m.n_layers {
                let lo = layer * t * row;
                let hi = (layer + 1) * t * row;
                linker
                    .scatter_group(
                        &mut k,
                        &mut v,
                        bucket,
                        span,
                        &e.k[lo..hi],
                        &e.v[lo..hi],
                        layer,
                        layer + 1,
                    )
                    .unwrap();
            }
        }
        assert_eq!(k, k_ref);
        assert_eq!(v, v_ref);

        // Bad payload length is rejected.
        let span = &l.reuse_spans[0];
        assert!(linker
            .scatter_group(&mut k, &mut v, bucket, span, &[0.0], &[0.0], 0, 1)
            .is_err());
    }

    #[test]
    fn linked_cache_scatters_chunk_rows_too() {
        let (m, l, ce, ie, doc_tokens) = chunk_fixture();
        let linker = Linker::new(&m);
        let bucket = 64;
        let (k, v) = linker.linked_cache(&l, &[&ce, &ie], bucket).unwrap();
        let row = m.n_heads * m.d_head;
        let chunk_span = l.reuse_spans[0];
        let t = doc_tokens.len();
        // Layer 0 rel 0 and layer 1 rel t-1 of the chunk both land.
        assert_eq!(&k[chunk_span.lo * row..chunk_span.lo * row + row], &ce.k[0..row]);
        let dst = bucket * row + (chunk_span.hi - 1) * row;
        let src = t * row + (t - 1) * row;
        assert_eq!(&v[dst..dst + row], &ce.v[src..src + row]);
        // Image rows land after the chunk.
        let img_span = l.reuse_spans[1];
        assert_eq!(&k[img_span.lo * row..img_span.lo * row + row], &ie.k[0..row]);
    }

    #[test]
    fn selective_inputs_pack_selection() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let pl = plan(Policy::MpicK(2), &l, &[]);
        let (k, v) = linker.linked_cache(&l, &[&e1, &e2], 32).unwrap();
        let si = linker.selective(&l, &[&e1, &e2], &pl, k, v, 32, 32).unwrap();
        assert_eq!(si.n_selected, pl.selected.len());
        let sel_pos = si.sel_pos.i32_data().unwrap();
        let sel_slot = si.sel_slot.i32_data().unwrap();
        // Real entries mirror plan.selected; padding points out of range.
        for (i, &slot) in pl.selected.iter().enumerate() {
            assert_eq!(sel_pos[i], slot as i32);
            assert_eq!(sel_slot[i], slot as i32);
        }
        for i in pl.selected.len()..32 {
            assert_eq!(sel_slot[i], 33);
        }
        // last_sel points at the highest-position selected token.
        let last_sel = si.last_sel.i32_data().unwrap()[0] as usize;
        assert_eq!(sel_pos[last_sel] as usize, l.len() - 1);
        // Image-head entries carry embeddings.
        let span1 = l.reuse_spans[0];
        let idx = pl.selected.iter().position(|&s| s == span1.lo).unwrap();
        assert_eq!(si.sel_is_img.f32_data().unwrap()[idx], 1.0);
        assert_eq!(si.sel_img_emb.f32_data().unwrap()[idx * m.d_model], 1.0);
    }

    #[test]
    fn selective_feeds_chunk_heads_by_vocab_id() {
        let (m, l, ce, ie, doc_tokens) = chunk_fixture();
        let linker = Linker::new(&m);
        let k_head = 2;
        let pl = plan(Policy::MpicK(k_head), &l, &[]);
        let (k, v) = linker.linked_cache(&l, &[&ce, &ie], 64).unwrap();
        let si = linker.selective(&l, &[&ce, &ie], &pl, k, v, 64, 64).unwrap();
        let sel_ids = si.sel_ids.i32_data().unwrap();
        let sel_is_img = si.sel_is_img.f32_data().unwrap();
        let chunk_span = l.reuse_spans[0];
        for j in 0..k_head {
            let slot = chunk_span.lo + j;
            let i = pl.selected.iter().position(|&s| s == slot).unwrap();
            assert_eq!(sel_ids[i], doc_tokens[j], "chunk head re-enters by vocab id");
            assert_eq!(sel_is_img[i], 0.0);
        }
        // Image heads still flagged as image with embeddings.
        let img_span = l.reuse_spans[1];
        let i = pl.selected.iter().position(|&s| s == img_span.lo).unwrap();
        assert_eq!(sel_is_img[i], 1.0);
    }

    #[test]
    fn selective_rejects_unselected_final_token() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let mut pl = plan(Policy::MpicK(2), &l, &[]);
        pl.selected.retain(|&s| s != l.len() - 1);
        let (k, v) = linker.linked_cache(&l, &[&e1, &e2], 32).unwrap();
        assert!(linker.selective(&l, &[&e1, &e2], &pl, k, v, 32, 32).is_err());
    }

    #[test]
    fn text_only_prefill_keeps_linked_positions() {
        let (m, l, _, _) = fixture();
        let linker = Linker::new(&m);
        let (inputs, mapping) = linker.text_only_prefill(&l, 16).unwrap();
        let pos = inputs.positions.i32_data().unwrap();
        for (packed, &slot) in mapping.iter().enumerate() {
            assert_eq!(pos[packed], slot as i32);
        }
        assert_eq!(mapping.len(), l.text_len());
        // Valid only for packed entries.
        let valid = inputs.valid.f32_data().unwrap();
        assert_eq!(valid.iter().filter(|&&x| x == 1.0).count(), mapping.len());
    }

    #[test]
    fn text_only_prefill_excludes_chunk_tokens() {
        let (m, l, _, _, _) = chunk_fixture();
        let linker = Linker::new(&m);
        let (_, mapping) = linker.text_only_prefill(&l, 32).unwrap();
        let chunk_span = l.reuse_spans[0];
        assert!(
            mapping.iter().all(|&s| s < chunk_span.lo || s >= chunk_span.hi),
            "chunk slots must not be recomputed by the text step"
        );
        assert_eq!(mapping.len(), l.text_len());
    }

    #[test]
    fn scatter_packed_rows_places_text_kv() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let bucket = 32;
        let (mut k, _) = linker.linked_cache(&l, &[&e1, &e2], bucket).unwrap();
        let packed_bucket = 16;
        let mapping = l.text_indices();
        let row = m.n_heads * m.d_head;
        let packed: Vec<f32> =
            (0..m.n_layers * packed_bucket * row).map(|i| 100.0 + i as f32).collect();
        linker.scatter_packed_rows(&mut k, bucket, &packed, packed_bucket, &mapping).unwrap();
        // First text slot row at layer 0 == packed row 0.
        let slot = mapping[0];
        assert_eq!(&k[slot * row..slot * row + row], &packed[0..row]);
        // Image rows untouched.
        let span1 = l.reuse_spans[0];
        assert_eq!(&k[span1.lo * row..span1.lo * row + row], &e1.k[0..row]);
    }

    #[test]
    fn deviation_reflects_difference() {
        let (m, l, e1, e2) = fixture();
        let linker = Linker::new(&m);
        let bucket = 32;
        let row = m.n_heads * m.d_head;
        // Fresh K equals stored for image 1, differs for image 2.
        let mut k0 = vec![0f32; bucket * row];
        let span1 = l.reuse_spans[0];
        for (rel, slot) in (span1.lo..span1.hi).enumerate() {
            k0[slot * row..(slot + 1) * row].copy_from_slice(&e1.k[rel * row..(rel + 1) * row]);
        }
        let dev = linker.layer0_deviation(&l, &[&e1, &e2], &k0, bucket).unwrap();
        for slot in span1.lo..span1.hi {
            assert_eq!(dev[slot], 0.0);
        }
        let span2 = l.reuse_spans[1];
        for slot in span2.lo..span2.hi {
            assert!(dev[slot] > 0.0);
        }
        for &slot in &l.text_indices() {
            assert_eq!(dev[slot], 0.0);
        }
    }

    #[test]
    fn entry_span_mismatch_is_rejected() {
        let (m, l, ce, ie, _) = chunk_fixture();
        let linker = Linker::new(&m);
        // Swapped order: entry kinds no longer match span kinds.
        assert!(linker.linked_cache(&l, &[&ie, &ce], 64).is_err());
        // Wrong token count for the chunk span.
        let bad = chunk_entry(&m, 7, 2, 5.0);
        assert!(linker.linked_cache(&l, &[&bad, &ie], 64).is_err());
    }
}
