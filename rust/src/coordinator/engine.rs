//! The serving engine: upload path (workflow ①), the four context-caching
//! inference paths (§6.1), greedy decode, and MRAG augmentation (④).
//!
//! All PJRT work stays on the engine's thread (`runtime` is `Rc`-based);
//! disk loads overlap via the transfer engine's pool. TTFT is measured
//! wall-clock from request ingestion to first-token logits, with the
//! fetch / link / execute breakdown recorded per request.

use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context};

use super::linker::Linker;
use super::metrics::Metrics;
use super::selection::{plan, Policy};
use crate::cache::{ChunkLibrary, DynamicLibrary, Reference, StaticLibrary};
use crate::kv::store::StoreConfig;
use crate::kv::{
    EntryInfo, KvKey, KvShape, KvStore, QuantLevel, SegmentKv, TransferEngine, TransferReport,
};
use crate::mm::{
    synth_patches, ChunkId, ChunkRef, ImageId, LinkedLayout, Namespace, Prompt, Segment,
    SegmentId, Tokenizer, UserId,
};
use crate::retriever::Retriever;
use crate::runtime::{ExecStats, ModelMeta, Runtime, Tensor};
use crate::util::json::Value;
use crate::util::threadpool::ThreadPool;
use crate::util::trace;
use crate::Result;

pub use crate::kv::EvictOutcome;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifact_dir: PathBuf,
    pub model: String,
    pub store: StoreConfig,
    pub pool_threads: usize,
    /// Default decode budget.
    pub max_new_tokens: usize,
    pub system_prompt: String,
    /// Require that prompt images are owned by the user or present in the
    /// dynamic library.
    pub enforce_ownership: bool,
    /// Per-user static-library quota (files).
    pub user_quota: usize,
    /// Per-namespace chunk-library quota (registered chunks).
    pub chunk_quota: usize,
    /// Consume MPIC-k fetches as a layer-group stream: groups splice
    /// into the linked cache while deeper groups still inflate off disk
    /// or the wire. `false` falls back to whole-entry fetch.
    pub streamed_fetch: bool,
    /// Leading layer groups the prefetch lane warms for queued
    /// requests' segments (partial-entry prefetch); `0` warms whole
    /// entries to the device tier like before.
    pub prefetch_groups: usize,
    /// Quality budget for compressed tiers: the store's deviation gate
    /// steps quant levels down until the measured layer-0 round-trip
    /// deviation fits this bound. Folded into
    /// `store.max_quant_deviation` at construction (tighter wins).
    pub max_quant_deviation: f32,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifact_dir: PathBuf::from(crate::DEFAULT_ARTIFACT_DIR),
            model: "mpic-sim-a".into(),
            store: StoreConfig::default(),
            pool_threads: 4,
            max_new_tokens: 16,
            system_prompt: "You are a helpful multimodal assistant".into(),
            enforce_ownership: false,
            user_quota: 64,
            chunk_quota: crate::cache::chunk_lib::DEFAULT_CHUNK_QUOTA,
            streamed_fetch: true,
            prefetch_groups: 1,
            max_quant_deviation: f32::INFINITY,
        }
    }
}

/// TTFT breakdown of one request.
#[derive(Debug, Clone, Default)]
pub struct TtftBreakdown {
    /// Transfer-engine wall time (load ∥ compute of image KV).
    pub fetch_s: f64,
    /// Linker assembly time (host).
    pub link_s: f64,
    /// Sum of artifact execution stats across prefill steps.
    pub exec: ExecStats,
    /// Number of engine invocations before the first token (1 for MPIC).
    pub steps: usize,
    /// Wall time ingestion → first-token logits.
    pub total_s: f64,
}

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub policy: String,
    /// Greedily decoded token ids (length ≤ max_new_tokens).
    pub tokens: Vec<i32>,
    /// First-token logits (vocab), for KL-based quality scoring.
    pub first_logits: Vec<f32>,
    pub ttft: TtftBreakdown,
    pub transfer: TransferReport,
    pub decode_s: f64,
    pub seq_len: usize,
    pub n_selected: usize,
    pub s_bucket: usize,
}

/// A prefilled sequence being decoded (possibly interleaved with others by
/// the scheduler's continuous-batching loop).
pub struct ActiveSeq {
    pub policy: String,
    pub prompt_len: usize,
    pub s_bucket: usize,
    pub max_new: usize,
    k_cache: Tensor,
    v_cache: Tensor,
    key_pos: Vec<i32>,
    key_valid: Vec<f32>,
    sink_bias: Vec<f32>,
    logits: Vec<f32>,
    first_logits: Vec<f32>,
    pub tokens: Vec<i32>,
    pub ttft: TtftBreakdown,
    pub transfer: TransferReport,
    pub n_selected: usize,
    decode_s: f64,
}

impl ActiveSeq {
    /// Total tokens this sequence occupies (for block accounting).
    pub fn footprint_tokens(&self) -> usize {
        self.prompt_len + self.max_new
    }

    pub fn finish(self) -> InferenceResult {
        InferenceResult {
            policy: self.policy,
            tokens: self.tokens,
            first_logits: self.first_logits,
            ttft: self.ttft,
            transfer: self.transfer,
            decode_s: self.decode_s,
            seq_len: self.prompt_len,
            n_selected: self.n_selected,
            s_bucket: self.s_bucket,
        }
    }
}

/// The engine.
pub struct Engine {
    runtime: Runtime,
    meta: ModelMeta,
    tokenizer: Tokenizer,
    store: Arc<KvStore>,
    pub static_lib: StaticLibrary,
    pub dynamic_lib: DynamicLibrary,
    pub chunk_lib: ChunkLibrary,
    retriever: RefCell<Retriever>,
    transfer: TransferEngine,
    /// Shared worker pool: drives the transfer engine's load lane and the
    /// serving pipeline's async upload lane (store write-through).
    pool: Arc<ThreadPool>,
    /// `Arc` so the `--metrics-addr` scrape thread can snapshot without
    /// borrowing the (`!Sync`) engine.
    pub metrics: Arc<Metrics>,
    /// Request-trace span sink + flight recorder (`debug.trace`).
    tracer: Arc<trace::Recorder>,
    cfg: EngineConfig,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Result<Engine> {
        let runtime = Runtime::open(&cfg.artifact_dir)?;
        let meta = runtime.model_meta(&cfg.model)?.clone();
        let tokenizer = Tokenizer::new(meta.vocab);
        let pool = Arc::new(ThreadPool::new(cfg.pool_threads));
        // The store gets a *dedicated* codec pool: transfer/upload work
        // runs on `pool`'s workers, and a worker can only fan chunked
        // codec work out across a *different* pool (blocking on its own
        // pool could deadlock — see ThreadPool::is_own_worker).
        let codec_pool = Arc::new(ThreadPool::new(cfg.pool_threads));
        let mut store_cfg = cfg.store.clone();
        store_cfg.max_quant_deviation =
            store_cfg.max_quant_deviation.min(cfg.max_quant_deviation);
        let store = Arc::new(KvStore::with_pool(store_cfg, codec_pool)?);
        let static_lib = StaticLibrary::new(Arc::clone(&store), cfg.user_quota);
        let dynamic_lib = DynamicLibrary::new(Arc::clone(&store));
        let chunk_lib = ChunkLibrary::with_quota(Arc::clone(&store), cfg.chunk_quota);
        let transfer = TransferEngine::new(Arc::clone(&pool));
        Ok(Engine {
            runtime,
            meta,
            tokenizer,
            store,
            static_lib,
            dynamic_lib,
            chunk_lib,
            retriever: RefCell::new(Retriever::new()),
            transfer,
            pool,
            metrics: Arc::new(Metrics::new()),
            tracer: Arc::new(trace::Recorder::default()),
            cfg,
        })
    }

    /// The engine's trace recorder: span sink, flight-recorder ring and
    /// slow-request log (`debug.trace`, `mpic trace`, `--slow-ms`).
    pub fn tracer(&self) -> &Arc<trace::Recorder> {
        &self.tracer
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn store(&self) -> &Arc<KvStore> {
        &self.store
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    /// The engine's shared worker pool (transfer loads, async store
    /// write-through). PJRT execution must stay on the engine's thread;
    /// only `Send` host-side work belongs here.
    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Switch the transfer engine between overlapped and serial fetch
    /// (ablation for Fig. 6).
    pub fn set_transfer_parallel(&mut self, parallel: bool) {
        self.transfer.parallel = parallel;
    }

    /// Install the remote tier behind local misses (cluster serving:
    /// `mpic serve --peers` installs a [`crate::cluster::PeerTransport`]
    /// here). The engine stays cluster-agnostic — it only sees the
    /// [`crate::kv::Transport`] trait.
    pub fn set_transport(&mut self, transport: std::sync::Arc<dyn crate::kv::Transport>) {
        self.transfer.set_transport(transport);
    }

    // ------------------------------------------------------------------
    // Upload path (workflow ①)
    // ------------------------------------------------------------------

    /// Compute an image's KV via the `encode_image_kv` artifact (default
    /// namespace).
    pub fn encode_image(&self, image: ImageId) -> Result<SegmentKv> {
        self.encode_image_in(&Namespace::default(), image)
    }

    /// Compute an image's KV, keyed under a tenant namespace. The pixels
    /// (and therefore the K/V values) are namespace-independent; only the
    /// cache key differs, which is what keeps tenants' entries apart.
    pub fn encode_image_in(&self, ns: &Namespace, image: ImageId) -> Result<SegmentKv> {
        let t = self.meta.img_tokens;
        let patches = synth_patches(image, t, self.meta.patch_dim);
        let art = Runtime::art_encode_image(&self.meta.name);
        let (outs, _) = self.runtime.execute(
            &art,
            &[Tensor::f32(vec![t, self.meta.patch_dim], patches)],
        )?;
        let shape = KvShape {
            layers: self.meta.n_layers,
            tokens: t,
            heads: self.meta.n_heads,
            d_head: self.meta.d_head,
            d_model: self.meta.d_model,
        };
        let kv = SegmentKv {
            key: KvKey::image(&self.meta.name, image).in_ns(ns),
            shape,
            emb: outs[0].f32_data()?.to_vec(),
            k: outs[1].f32_data()?.to_vec(),
            v: outs[2].f32_data()?.to_vec(),
        };
        kv.validate()?;
        Ok(kv)
    }

    /// Compute a text chunk's KV: a canonical text-only `prefill_full` at
    /// positions `0..n`, exactly like stored image KV (which sits at
    /// canonical `0..img_tokens`). The rows are position-stale wherever a
    /// later prompt splices them; MPIC-k's head recompute repairs the
    /// sink, which is the paper's position-independence recipe applied to
    /// text.
    pub fn encode_chunk_kv(&self, chunk: ChunkId, tokens: &[i32]) -> Result<SegmentKv> {
        self.encode_chunk_kv_in(&Namespace::default(), chunk, tokens)
    }

    /// Namespaced variant of [`Engine::encode_chunk_kv`].
    pub fn encode_chunk_kv_in(
        &self,
        ns: &Namespace,
        chunk: ChunkId,
        tokens: &[i32],
    ) -> Result<SegmentKv> {
        let n = tokens.len();
        anyhow::ensure!(n >= 1, "chunk must tokenize to at least one token");
        let bucket = self.runtime.manifest().seq_bucket_for(n)?;
        // A synthetic text-only layout at canonical positions 0..n; the
        // linker builds the prefill_full activation set from it.
        let layout = LinkedLayout {
            tokens: tokens.iter().map(|&t| crate::mm::TokenKind::Text(t)).collect(),
            reuse_spans: Vec::new(),
            sys_len: 0,
        };
        let linker = Linker::new(&self.meta);
        let inputs = linker.full_prefill(&layout, &[], bucket)?;
        let art = Runtime::art_prefill_full(&self.meta.name, bucket);
        let (outs, _) = self.runtime.execute(&art, &inputs.to_vec())?;
        let mut it = outs.into_iter();
        let _logits = it.next().unwrap();
        let k_full = it.next().unwrap();
        let v_full = it.next().unwrap();
        // Extract rows 0..n of every layer from the [L, bucket, H, Dh]
        // cache outputs into the compact [L, n, H, Dh] entry.
        let (l, row) = (self.meta.n_layers, self.meta.n_heads * self.meta.d_head);
        let shape = KvShape {
            layers: l,
            tokens: n,
            heads: self.meta.n_heads,
            d_head: self.meta.d_head,
            d_model: self.meta.d_model,
        };
        let extract = |full: &Tensor| -> Result<Vec<f32>> {
            let data = full.f32_data()?;
            let mut out = vec![0f32; l * n * row];
            for layer in 0..l {
                let src = layer * bucket * row;
                let dst = layer * n * row;
                out[dst..dst + n * row].copy_from_slice(&data[src..src + n * row]);
            }
            Ok(out)
        };
        let kv = SegmentKv {
            key: KvKey::chunk(&self.meta.name, chunk).in_ns(ns),
            shape,
            emb: Vec::new(),
            k: extract(&k_full)?,
            v: extract(&v_full)?,
        };
        kv.validate()?;
        Ok(kv)
    }

    /// Compute a segment's KV from scratch, whichever kind it is (the
    /// transfer engine's miss lane; chunk misses re-derive tokens from
    /// the chunk library, scoped to the key's namespace).
    pub fn compute_segment_kv(&self, key: &KvKey) -> Result<SegmentKv> {
        match key.seg {
            SegmentId::Image(image) => self.encode_image_in(&key.ns, image),
            SegmentId::Chunk(chunk) => {
                let tokens = self.chunk_lib.tokens_in(&key.ns, chunk)?;
                self.encode_chunk_kv_in(&key.ns, chunk, &tokens)
            }
        }
    }

    /// Upload: synth pixels → encode → store (device + disk write-through)
    /// → register in the user's static library (default namespace).
    pub fn upload_image(&self, user: UserId, handle: &str) -> Result<ImageId> {
        self.upload_image_in(&Namespace::default(), user, handle)
    }

    /// Namespaced upload: the KV entry and the registration both live
    /// under the tenant's namespace.
    pub fn upload_image_in(&self, ns: &Namespace, user: UserId, handle: &str) -> Result<ImageId> {
        let image = ImageId::from_handle(handle);
        let t0 = Instant::now();
        let kv = self.encode_image_in(ns, image).context("upload: encode")?;
        self.store.put(kv)?;
        self.static_lib.register_in(ns, user, handle, image)?;
        self.metrics.record_upload(t0.elapsed().as_secs_f64());
        Ok(image)
    }

    /// Upload a text chunk (workflow ① for text): tokenize → canonical
    /// text-only prefill → extract K/V rows → store → register in the
    /// chunk library so prompts can reference `CHUNK#HANDLE`.
    pub fn upload_chunk(&self, handle: &str, text: &str) -> Result<ChunkId> {
        self.upload_chunk_in(&Namespace::default(), handle, text)
    }

    /// Namespaced variant of [`Engine::upload_chunk`].
    pub fn upload_chunk_in(&self, ns: &Namespace, handle: &str, text: &str) -> Result<ChunkId> {
        let chunk = ChunkId::from_handle(handle);
        let tokens = self.tokenizer.encode(text);
        anyhow::ensure!(!tokens.is_empty(), "chunk {handle:?} has no tokens");
        let t0 = Instant::now();
        // Quota-check before the expensive prefill (cheap rejection), but
        // register only *after* the KV landed in the store: a failed
        // re-upload must not leave fresh tokens paired with stale stored
        // KV, which would poison every later request using the chunk.
        self.chunk_lib.ensure_capacity(ns, chunk)?;
        let kv = self.encode_chunk_kv_in(ns, chunk, &tokens).context("upload_chunk: prefill")?;
        self.store.put(kv)?;
        self.chunk_lib.register_in(ns, handle, text, tokens)?;
        self.metrics.record_upload(t0.elapsed().as_secs_f64());
        Ok(chunk)
    }

    /// Admin path: (re)index a dynamic-library image reference with its KV.
    pub fn add_reference(&self, handle: &str, description: &str) -> Result<ImageId> {
        self.add_reference_in(&Namespace::default(), handle, description)
    }

    /// Namespaced variant of [`Engine::add_reference`].
    pub fn add_reference_in(
        &self,
        ns: &Namespace,
        handle: &str,
        description: &str,
    ) -> Result<ImageId> {
        let image = ImageId::from_handle(handle);
        let kv = self.encode_image_in(ns, image)?;
        self.store.put(kv)?;
        self.dynamic_lib.add(Reference::image(image, description).in_ns(ns));
        Ok(image)
    }

    /// Admin path: upload a text chunk *and* index it for MRAG retrieval,
    /// so `mrag_augment` can splice its cached KV instead of raw text.
    pub fn add_chunk_reference(
        &self,
        handle: &str,
        text: &str,
        description: &str,
    ) -> Result<ChunkId> {
        self.add_chunk_reference_in(&Namespace::default(), handle, text, description)
    }

    /// Namespaced variant of [`Engine::add_chunk_reference`].
    pub fn add_chunk_reference_in(
        &self,
        ns: &Namespace,
        handle: &str,
        text: &str,
        description: &str,
    ) -> Result<ChunkId> {
        let chunk = self.upload_chunk_in(ns, handle, text)?;
        self.dynamic_lib.add(Reference {
            seg: SegmentId::Chunk(chunk),
            ns: ns.clone(),
            description: description.to_string(),
        });
        Ok(chunk)
    }

    // ------------------------------------------------------------------
    // MRAG (workflow ④)
    // ------------------------------------------------------------------

    /// Retrieve the top-k dynamic references for a query and append them to
    /// the prompt (the decode-time retrieval trigger is emulated by an
    /// explicit call — see DESIGN.md §2). Retrieval is scoped to the
    /// prompt's namespace: a tenant only ever splices its own references.
    /// Image hits splice as image segments; chunk hits splice as *cached
    /// chunk references* — their stored KV is reused instead of
    /// re-prefetching raw text.
    pub fn mrag_augment(&self, prompt: &Prompt, top_k: usize) -> Result<(Prompt, Vec<SegmentId>)> {
        let mut r = self.retriever.borrow_mut();
        r.sync(&self.dynamic_lib);
        if r.is_empty() {
            bail!("dynamic library is empty");
        }
        let query: Vec<String> = prompt
            .segments
            .iter()
            .filter_map(|s| match s {
                Segment::Text(t) => Some(t.clone()),
                _ => None,
            })
            .collect();
        let hits = r.search_in(&prompt.ns, &query.join(" "), top_k);
        let mut out = prompt.clone();
        let mut ids = Vec::new();
        for (seg, _score) in hits {
            out = out.text("retrieved reference");
            out = match seg {
                SegmentId::Image(image) => out.image(image),
                SegmentId::Chunk(chunk) => {
                    let tokens = self.chunk_lib.tokens_in(&prompt.ns, chunk)?;
                    out.chunk(ChunkRef::resolved_shared(chunk, tokens))
                }
            };
            ids.push(seg);
        }
        Ok((out, ids))
    }

    // ------------------------------------------------------------------
    // Inference
    // ------------------------------------------------------------------

    /// Ownership gates apply to images (Static-Library files are
    /// user-private). Chunks are shared context (RAG documents) and are
    /// always referenceable once uploaded.
    fn check_ownership(&self, prompt: &Prompt) -> Result<()> {
        if !self.cfg.enforce_ownership {
            return Ok(());
        }
        for image in prompt.images() {
            let owned = self.static_lib.owns_in(&prompt.ns, prompt.user, image);
            let public = self.dynamic_lib.by_image_in(&prompt.ns, image).is_ok();
            if !owned && !public {
                bail!("user {:?} does not own image {image:?}", prompt.user);
            }
        }
        Ok(())
    }

    fn has_unresolved_chunks(prompt: &Prompt) -> bool {
        prompt
            .segments
            .iter()
            .any(|s| matches!(s, Segment::Chunk(c) if !c.is_resolved()))
    }

    /// Replace unresolved `CHUNK#` references with their canonical token
    /// streams from the chunk library (shared `Arc`s — no token copies),
    /// resolving against the prompt's namespace. Errors on chunks this
    /// tenant never uploaded. Only called when the prompt actually holds
    /// an unresolved reference.
    fn resolve_chunks(&self, prompt: &Prompt) -> Result<Prompt> {
        let mut out = prompt.clone();
        for seg in out.segments.iter_mut() {
            if let Segment::Chunk(c) = seg {
                if !c.is_resolved() {
                    c.tokens = self.chunk_lib.tokens_in(&prompt.ns, c.id)?;
                }
            }
        }
        Ok(out)
    }

    /// Resolve chunk references and build the linked layout (scheduler
    /// footprint estimates use this too, so chunk tokens count).
    /// Chunk-free prompts (the common case) build straight from the
    /// borrowed prompt — no clone on the hot path.
    pub fn layout(&self, prompt: &Prompt) -> Result<LinkedLayout> {
        let build = |p: &Prompt| {
            LinkedLayout::build(p, &self.tokenizer, self.meta.img_tokens, &self.cfg.system_prompt)
        };
        if Self::has_unresolved_chunks(prompt) {
            Ok(build(&self.resolve_chunks(prompt)?))
        } else {
            Ok(build(prompt))
        }
    }

    /// Warm the KV entries of not-yet-admitted requests toward the device
    /// tier on idle pool workers (the prefetch lane — the serving pipeline
    /// calls this between decode rounds with the segment refs of queued
    /// requests). Non-blocking; returns the number of jobs dispatched.
    pub fn prefetch_segments(&self, segments: &[(Namespace, SegmentId)]) -> usize {
        if segments.is_empty() {
            return 0;
        }
        let keys: Vec<KvKey> = segments
            .iter()
            .map(|(ns, seg)| KvKey::segment(&self.meta.name, ns, *seg))
            .collect();
        if self.cfg.prefetch_groups > 0 {
            // Partial-entry prefetch: only the shallow layer groups a
            // streamed fetch consumes first — a fraction of the warm
            // bandwidth per queued request.
            self.transfer.prefetch_partial(&self.store, &keys, self.cfg.prefetch_groups)
        } else {
            self.transfer.prefetch(&self.store, &keys)
        }
    }

    /// Fetch the KV entries for every reuse span (order = span order),
    /// loading hits in parallel with computing misses. Entries come back
    /// as `Arc`s straight out of the store — no KV bytes are copied on a
    /// hit, and duplicate spans share one fetch.
    fn fetch_entries(
        &self,
        layout: &LinkedLayout,
        ns: &Namespace,
    ) -> Result<(Vec<Arc<SegmentKv>>, TransferReport)> {
        let keys: Vec<KvKey> = layout
            .reuse_spans
            .iter()
            .map(|span| KvKey::segment(&self.meta.name, ns, span.seg))
            .collect();
        self.transfer.fetch(&self.store, &keys, |key| self.compute_segment_kv(key))
    }

    /// Streamed MPIC-k fetch: build the linked dummy cache by splicing
    /// layer groups into it as the transfer lane inflates them, so the
    /// scatter (and whatever else the caller does between groups) runs
    /// while deeper groups are still on disk or on the wire. Returns the
    /// fetched entries (span order), the assembled `k`/`v` caches, the
    /// transfer report (with `stall_us`/`overlap_us`) and the seconds
    /// spent scattering — link work that overlapped the load.
    #[allow(clippy::type_complexity)]
    fn fetch_streamed_linked(
        &self,
        layout: &LinkedLayout,
        ns: &Namespace,
        linker: &Linker,
        bucket: usize,
    ) -> Result<(Vec<Arc<SegmentKv>>, Vec<f32>, Vec<f32>, TransferReport, f64)> {
        let keys: Vec<KvKey> = layout
            .reuse_spans
            .iter()
            .map(|span| KvKey::segment(&self.meta.name, ns, span.seg))
            .collect();
        let mut stream = self.transfer.fetch_streamed(&self.store, &keys);
        let (mut k, mut v) = linker.empty_linked_cache(bucket);
        let slots = stream.slots().to_vec();
        let mut scatter_s = 0.0;
        // Deepest layer already spliced per span; groups arrive
        // shallow-first per slot, so this is a contiguous frontier.
        let mut layers_done = vec![0usize; layout.reuse_spans.len()];
        while let Some(ev) = stream.next_group() {
            let t0 = Instant::now();
            for (i, span) in layout.reuse_spans.iter().enumerate() {
                if slots[i] != ev.slot {
                    continue;
                }
                linker.scatter_group(
                    &mut k,
                    &mut v,
                    bucket,
                    span,
                    &ev.group.k,
                    &ev.group.v,
                    ev.group.layer_lo,
                    ev.group.layer_hi,
                )?;
                layers_done[i] = layers_done[i].max(ev.group.layer_hi);
            }
            scatter_s += t0.elapsed().as_secs_f64();
        }
        let (entries, report) = stream.finish(|key| self.compute_segment_kv(key))?;
        // A fully streamed span's entry was assembled from the very
        // groups spliced above — nothing left to do. Anything else
        // (device fast-path hit, peer-served full container, corrupt
        // tail, recompute) splices the *whole* entry: a partially
        // streamed prefix may predate the entry `finish` returned, so
        // mixing the two generations layer-wise would corrupt the cache.
        let t0 = Instant::now();
        let l = self.meta.n_layers;
        for (i, span) in layout.reuse_spans.iter().enumerate() {
            if layers_done[i] >= l {
                continue;
            }
            let e = &entries[i];
            linker.scatter_group(&mut k, &mut v, bucket, span, &e.k, &e.v, 0, l)?;
        }
        scatter_s += t0.elapsed().as_secs_f64();
        Ok((entries, k, v, report, scatter_s))
    }

    /// Prefill one request under a context-caching policy, producing an
    /// [`ActiveSeq`] ready for (interleaved) decoding. TTFT is fully
    /// accounted by the time this returns.
    pub fn prefill(&self, prompt: &Prompt, policy: Policy, max_new: usize) -> Result<ActiveSeq> {
        self.check_ownership(prompt)?;
        let layout = self.layout(prompt)?;
        let len = layout.len();
        anyhow::ensure!(len >= 2, "prompt too short");
        let manifest = self.runtime.manifest();
        // One bucket serves prefill *and* the decode tail.
        let s_bucket = manifest.seq_bucket_for(len + max_new)?;
        let linker = Linker::new(&self.meta);

        let t_request = Instant::now();
        // MPIC-k consumes the fetch as a layer-group *stream* inside its
        // arm below (groups splice into the linked cache while deeper
        // groups still inflate); the other policies fetch whole entries
        // up front.
        let streamed = self.cfg.streamed_fetch && matches!(policy, Policy::MpicK(_));
        let (entries, mut transfer) = if streamed {
            (Vec::new(), TransferReport::default())
        } else {
            let (entries, transfer) = self.fetch_entries(&layout, &prompt.ns)?;
            record_fetch_span(t_request, &transfer);
            (entries, transfer)
        };
        let entry_refs: Vec<&SegmentKv> = entries.iter().map(|e| e.as_ref()).collect();
        let mut ttft =
            TtftBreakdown { fetch_s: t_request.elapsed().as_secs_f64(), ..Default::default() };
        let (first_logits, k_cache, v_cache, n_selected);

        match policy {
            Policy::Prefix => {
                let t_link = Instant::now();
                let inputs = linker.full_prefill(&layout, &entry_refs, s_bucket)?;
                ttft.link_s += t_link.elapsed().as_secs_f64();
                trace::record("link", t_link, &[]);
                let art = Runtime::art_prefill_full(&self.meta.name, s_bucket);
                let t_exec = Instant::now();
                let (outs, es) = self.runtime.execute(&art, &inputs.to_vec())?;
                trace::record("prefill", t_exec, &[("policy", Value::str(policy.name()))]);
                ttft.exec.add(&es);
                ttft.steps = 1;
                let mut it = outs.into_iter();
                first_logits = it.next().unwrap().f32_data()?.to_vec();
                k_cache = it.next().unwrap();
                v_cache = it.next().unwrap();
                n_selected = len;
            }

            Policy::MpicK(_) => {
                // Single-pass selective attention over the dummy+linked cache.
                let pl = plan(policy, &layout, &[]);
                n_selected = pl.selected.len();
                let (s_sel, n_bucket) = self.selective_bucket(s_bucket, n_selected)?;
                let (sentries, k, v) = if streamed {
                    // Layer groups splice into the linked cache as codec
                    // workers inflate them: the scatter work is the
                    // compute the loader hides (`overlap_us`), so
                    // `fetch_s` and `link_s` overlap on the wall clock
                    // instead of adding up.
                    let (sentries, k, v, rep, scatter_s) =
                        self.fetch_streamed_linked(&layout, &prompt.ns, &linker, s_sel)?;
                    record_fetch_span(t_request, &rep);
                    ttft.fetch_s = rep.wall_s;
                    ttft.link_s += scatter_s;
                    transfer = rep;
                    (sentries, k, v)
                } else {
                    let t_link = Instant::now();
                    let (k, v) = linker.linked_cache(&layout, &entry_refs, s_sel)?;
                    ttft.link_s += t_link.elapsed().as_secs_f64();
                    (entries.clone(), k, v)
                };
                let srefs: Vec<&SegmentKv> = sentries.iter().map(|e| e.as_ref()).collect();
                let t_link = Instant::now();
                let si = linker.selective(&layout, &srefs, &pl, k, v, s_sel, n_bucket)?;
                ttft.link_s += t_link.elapsed().as_secs_f64();
                trace::record("link", t_link, &[]);
                let art = Runtime::art_prefill_selective(&self.meta.name, s_sel, n_bucket);
                let t_exec = Instant::now();
                let (outs, es) = self.runtime.execute(&art, &si.to_vec())?;
                trace::record(
                    "prefill",
                    t_exec,
                    &[
                        ("policy", Value::str(policy.name())),
                        ("n_selected", Value::num(n_selected as f64)),
                    ],
                );
                ttft.exec.add(&es);
                ttft.steps = 1;
                let mut it = outs.into_iter();
                first_logits = it.next().unwrap().f32_data()?.to_vec();
                k_cache = it.next().unwrap();
                v_cache = it.next().unwrap();
            }

            Policy::FullReuse => {
                // Step A: text-only prefill at linked positions.
                let (text_kv, mapping, es_a, link_a) = self.text_prefill(&linker, &layout)?;
                ttft.link_s += link_a;
                ttft.exec.add(&es_a);
                // Concatenate: image KV + text KV into the linked cache.
                let t_link = Instant::now();
                let (mut k, mut v) = linker.linked_cache(&layout, &entry_refs, s_bucket)?;
                let (tk, tv, text_bucket) = text_kv;
                linker.scatter_packed_rows(&mut k, s_bucket, &tk, text_bucket, &mapping)?;
                linker.scatter_packed_rows(&mut v, s_bucket, &tv, text_bucket, &mapping)?;
                let slots = super::linker::SlotArrays::build(&layout, &self.meta, s_bucket);
                ttft.link_s += t_link.elapsed().as_secs_f64();
                trace::record("link", t_link, &[]);

                // Step B: recompute the final prompt token over the blended
                // cache to produce the first output token's logits.
                let last = len - 1;
                let last_id = match layout.tokens[last] {
                    crate::mm::TokenKind::Text(id) => id,
                    crate::mm::TokenKind::Chunk { tok, .. } => tok,
                    crate::mm::TokenKind::Image { .. } => {
                        bail!("full-reuse requires the prompt to end with text")
                    }
                };
                let kvdims =
                    vec![self.meta.n_layers, s_bucket, self.meta.n_heads, self.meta.d_head];
                let (kp, kvld, sb) = slots.tensors();
                let art = Runtime::art_decode_step(&self.meta.name, s_bucket);
                let t_exec = Instant::now();
                let (outs, es_b) = self.runtime.execute(
                    &art,
                    &[
                        Tensor::scalar_i32(last_id),
                        Tensor::scalar_i32(last as i32),
                        Tensor::scalar_i32(last as i32),
                        Tensor::f32(kvdims.clone(), k),
                        Tensor::f32(kvdims, v),
                        kp,
                        kvld,
                        sb,
                    ],
                )?;
                trace::record("prefill", t_exec, &[("policy", Value::str(policy.name()))]);
                ttft.exec.add(&es_b);
                ttft.steps = 2;
                let mut it = outs.into_iter();
                first_logits = it.next().unwrap().f32_data()?.to_vec();
                k_cache = it.next().unwrap();
                v_cache = it.next().unwrap();
                n_selected = layout.text_len();
            }

            Policy::CacheBlend(_) => {
                // Deviation estimation on the linked layout (layer-0 K).
                let t_link = Instant::now();
                let full_inputs = linker.full_prefill(&layout, &entry_refs, s_bucket)?;
                ttft.link_s += t_link.elapsed().as_secs_f64();
                let art0 = Runtime::art_layer0_k(&self.meta.name, s_bucket);
                let (outs0, es0) = self.runtime.execute(&art0, &full_inputs.layer0_vec())?;
                ttft.exec.add(&es0);
                let t_dev = Instant::now();
                let dev = linker.layer0_deviation(
                    &layout,
                    &entry_refs,
                    outs0[0].f32_data()?,
                    s_bucket,
                )?;
                let pl = plan(policy, &layout, &dev);
                ttft.link_s += t_dev.elapsed().as_secs_f64();
                n_selected = pl.selected.len() + layout.text_len();

                // Step A: text prefill, exactly like full reuse.
                let (text_kv, mapping, es_a, link_a) = self.text_prefill(&linker, &layout)?;
                ttft.link_s += link_a;
                ttft.exec.add(&es_a);

                // Step B: selective pass over (image ∪ text) cache.
                let t_link2 = Instant::now();
                let (mut k, mut v) = linker.linked_cache(&layout, &entry_refs, s_bucket)?;
                let (tk, tv, text_bucket) = text_kv;
                linker.scatter_packed_rows(&mut k, s_bucket, &tk, text_bucket, &mapping)?;
                linker.scatter_packed_rows(&mut v, s_bucket, &tv, text_bucket, &mapping)?;
                let (_, n_bucket) = self.selective_bucket(s_bucket, pl.selected.len())?;
                let si = linker.selective(&layout, &entry_refs, &pl, k, v, s_bucket, n_bucket)?;
                ttft.link_s += t_link2.elapsed().as_secs_f64();
                trace::record("link", t_link2, &[]);
                let art = Runtime::art_prefill_selective(&self.meta.name, s_bucket, n_bucket);
                let t_exec = Instant::now();
                let (outs, es) = self.runtime.execute(&art, &si.to_vec())?;
                trace::record(
                    "prefill",
                    t_exec,
                    &[
                        ("policy", Value::str(policy.name())),
                        ("n_selected", Value::num(n_selected as f64)),
                    ],
                );
                ttft.exec.add(&es);
                ttft.steps = 3; // estimate + text prefill + blend
                let mut it = outs.into_iter();
                first_logits = it.next().unwrap().f32_data()?.to_vec();
                k_cache = it.next().unwrap();
                v_cache = it.next().unwrap();
            }
        }

        ttft.total_s = t_request.elapsed().as_secs_f64();

        let slots = super::linker::SlotArrays::build(&layout, &self.meta, s_bucket);
        Ok(ActiveSeq {
            policy: policy.name(),
            prompt_len: len,
            s_bucket,
            max_new,
            k_cache,
            v_cache,
            key_pos: slots.key_pos,
            key_valid: slots.key_valid,
            sink_bias: slots.sink_bias,
            logits: first_logits.clone(),
            first_logits,
            tokens: Vec::with_capacity(max_new),
            ttft,
            transfer,
            n_selected,
            decode_s: 0.0,
        })
    }

    /// Run one request end to end: prefill + greedy decode to the budget.
    pub fn infer(&self, prompt: &Prompt, policy: Policy, max_new: usize) -> Result<InferenceResult> {
        let mut seq = self.prefill(prompt, policy, max_new)?;
        while self.decode_one(&mut seq)? {}
        let result = seq.finish();
        self.metrics.record_request(&result);
        Ok(result)
    }

    /// One greedy decode step for an active sequence. Returns `false` when
    /// the sequence has exhausted its budget or bucket.
    pub fn decode_one(&self, seq: &mut ActiveSeq) -> Result<bool> {
        if seq.tokens.len() >= seq.max_new {
            return Ok(false);
        }
        let t0 = Instant::now();
        let next = argmax(&seq.logits);
        seq.tokens.push(next);
        let pos = seq.prompt_len + seq.tokens.len() - 1;
        if pos >= seq.s_bucket || seq.tokens.len() >= seq.max_new {
            seq.decode_s += t0.elapsed().as_secs_f64();
            trace::record("decode", t0, &[("pos", Value::num(pos as f64))]);
            return Ok(false);
        }
        seq.key_pos[pos] = pos as i32;
        seq.key_valid[pos] = 1.0;
        // Perf iteration 2 (EXPERIMENTS.md §Perf): the rows-only decode
        // artifact returns just this token's K/V rows; the host patches its
        // authoritative cache in place, halving the per-step copy volume
        // versus the full-cache-output variant.
        let art_decode = Runtime::art_decode_step_rows(&self.meta.name, seq.s_bucket);
        let tok_t = Tensor::scalar_i32(next);
        let pos_t = Tensor::scalar_i32(pos as i32);
        let slot_t = Tensor::scalar_i32(pos as i32);
        let kp_t = Tensor::i32(vec![seq.s_bucket], seq.key_pos.clone());
        let kv_t = Tensor::f32(vec![seq.s_bucket], seq.key_valid.clone());
        let sb_t = Tensor::f32(vec![seq.s_bucket], seq.sink_bias.clone());
        let args: Vec<&Tensor> = vec![
            &tok_t, &pos_t, &slot_t, &seq.k_cache, &seq.v_cache, &kp_t, &kv_t, &sb_t,
        ];
        let (outs, es) = self.runtime.execute(&art_decode, &args)?;
        self.metrics.record_decode_step(es.total_s());
        let mut it = outs.into_iter();
        seq.logits = it.next().unwrap().f32_data()?.to_vec();
        let k_row = it.next().unwrap();
        let v_row = it.next().unwrap();
        // Patch the new rows into the host caches at `pos`.
        let (l, h, dh) = (self.meta.n_layers, self.meta.n_heads, self.meta.d_head);
        let row = h * dh;
        let s_bucket = seq.s_bucket;
        for (cache, rows) in [(&mut seq.k_cache, k_row), (&mut seq.v_cache, v_row)] {
            let data = cache.f32_data_mut()?;
            let src = rows.f32_data()?;
            for layer in 0..l {
                let dst = (layer * s_bucket + pos) * row;
                data[dst..dst + row].copy_from_slice(&src[layer * row..(layer + 1) * row]);
            }
        }
        seq.decode_s += t0.elapsed().as_secs_f64();
        trace::record("decode", t0, &[("pos", Value::num(pos as f64))]);
        Ok(seq.tokens.len() < seq.max_new)
    }

    /// Step A of the two-step baselines: packed text-only prefill.
    /// Returns ((k, v, bucket), mapping, exec stats, link seconds).
    #[allow(clippy::type_complexity)]
    fn text_prefill(
        &self,
        linker: &Linker,
        layout: &LinkedLayout,
    ) -> Result<((Vec<f32>, Vec<f32>, usize), Vec<usize>, ExecStats, f64)> {
        let n_text = layout.text_len();
        let bucket = self.runtime.manifest().seq_bucket_for(n_text)?;
        let t_link = Instant::now();
        let (inputs, mapping) = linker.text_only_prefill(layout, bucket)?;
        let link_s = t_link.elapsed().as_secs_f64();
        let art = Runtime::art_prefill_full(&self.meta.name, bucket);
        let (outs, es) = self.runtime.execute(&art, &inputs.to_vec())?;
        let mut it = outs.into_iter();
        let _logits = it.next().unwrap();
        let k = it.next().unwrap().f32_data()?.to_vec();
        let v = it.next().unwrap().f32_data()?.to_vec();
        Ok(((k, v, bucket), mapping, es, link_s))
    }

    /// Resolve the (S, N) selective bucket: S fixed by the decode tail,
    /// N = smallest bucket holding `n_sel`.
    fn selective_bucket(&self, s_bucket: usize, n_sel: usize) -> Result<(usize, usize)> {
        let manifest = self.runtime.manifest();
        manifest
            .selective_buckets
            .iter()
            .copied()
            .filter(|&(s, n)| s == s_bucket && n >= n_sel)
            .min_by_key(|&(_, n)| n)
            .ok_or_else(|| {
                anyhow!(
                    "no selective bucket (s={s_bucket}, n>={n_sel}); selected too many tokens"
                )
            })
    }

    // ------------------------------------------------------------------
    // Analysis entrypoints (Figs. 4, 8, 11)
    // ------------------------------------------------------------------

    /// Full prefill returning the raw K tensor (Fig. 8 K-distance bench).
    pub fn full_prefill_kv(&self, prompt: &Prompt) -> Result<(LinkedLayout, Tensor, Tensor)> {
        let layout = self.layout(prompt)?;
        let s_bucket = self.runtime.manifest().seq_bucket_for(layout.len())?;
        let (entries, _) = self.fetch_entries(&layout, &prompt.ns)?;
        let entry_refs: Vec<&SegmentKv> = entries.iter().map(|e| e.as_ref()).collect();
        let linker = Linker::new(&self.meta);
        let inputs = linker.full_prefill(&layout, &entry_refs, s_bucket)?;
        let art = Runtime::art_prefill_full(&self.meta.name, s_bucket);
        let (outs, _) = self.runtime.execute(&art, &inputs.to_vec())?;
        let mut it = outs.into_iter();
        let _logits = it.next().unwrap();
        Ok((layout, it.next().unwrap(), it.next().unwrap()))
    }

    /// Debug prefill: per-layer attention row of the last query plus the
    /// full layer-0 attention matrix (Figs. 4 & 11).
    pub fn debug_attention(&self, prompt: &Prompt) -> Result<(LinkedLayout, Tensor, Tensor)> {
        let layout = self.layout(prompt)?;
        let s_bucket = self.runtime.manifest().debug_bucket_for(layout.len())?;
        let (entries, _) = self.fetch_entries(&layout, &prompt.ns)?;
        let entry_refs: Vec<&SegmentKv> = entries.iter().map(|e| e.as_ref()).collect();
        let linker = Linker::new(&self.meta);
        let inputs = linker.full_prefill(&layout, &entry_refs, s_bucket)?;
        let art = Runtime::art_prefill_debug(&self.meta.name, s_bucket);
        let (outs, _) = self.runtime.execute(&art, &inputs.to_vec())?;
        let mut it = outs.into_iter();
        let _logits = it.next().unwrap();
        Ok((layout, it.next().unwrap(), it.next().unwrap()))
    }

    /// Fetch an image's stored KV (benches/Fig. 8: compare stored vs
    /// fresh). Shares the store's allocation — a device hit copies nothing.
    pub fn stored_kv(&self, image: ImageId) -> Option<Arc<SegmentKv>> {
        self.store.get(&KvKey::image(&self.meta.name, image)).map(|(kv, _)| kv)
    }

    /// Fetch a chunk's stored KV (benches: compare stored vs fresh).
    pub fn stored_chunk_kv(&self, chunk: ChunkId) -> Option<Arc<SegmentKv>> {
        self.store.get(&KvKey::chunk(&self.meta.name, chunk)).map(|(kv, _)| kv)
    }

    // ------------------------------------------------------------------
    // Cache management (the `cache.*` API surface)
    // ------------------------------------------------------------------

    /// The store key a handle resolves to under this engine's model and
    /// the caller's namespace. Handles are content-derived, so resolution
    /// needs no registry: `CHUNK#...` handles address chunk entries,
    /// everything else images.
    pub fn kv_key(&self, ns: &Namespace, handle: &str) -> KvKey {
        if handle.starts_with("CHUNK#") {
            KvKey::chunk(&self.meta.name, ChunkId::from_handle(handle)).in_ns(ns)
        } else {
            KvKey::image(&self.meta.name, ImageId::from_handle(handle)).in_ns(ns)
        }
    }

    /// Residency report over one namespace's cached segments (Static,
    /// Dynamic and Chunk Library entries share the tiered store). The
    /// `cache.list` op scopes to the caller's tenant; the default
    /// namespace sees exactly the pre-v3 (un-namespaced) entries.
    pub fn cache_entries(&self, ns: &Namespace) -> Vec<EntryInfo> {
        self.store.entries().into_iter().filter(|e| e.key.ns == *ns).collect()
    }

    /// Residency of one handle's cache entry, or `None` when absent.
    pub fn cache_stat(&self, ns: &Namespace, handle: &str) -> Option<EntryInfo> {
        self.store.entry_info(&self.kv_key(ns, handle))
    }

    /// Pin (or unpin) a handle's entry — the v2 compat surface (an
    /// infinite lease under the hood). Returns `false` when not resident.
    pub fn cache_pin(&self, ns: &Namespace, handle: &str, pinned: bool) -> bool {
        self.store.set_pinned(&self.kv_key(ns, handle), pinned)
    }

    /// Grant a bounded-lifetime lease on a handle's entry (`cache.lease`).
    /// `ttl: None` = infinite. `None` result = not resident.
    pub fn cache_lease(
        &self,
        ns: &Namespace,
        handle: &str,
        ttl: Option<std::time::Duration>,
    ) -> Option<crate::kv::LeaseInfo> {
        self.store.lease(&self.kv_key(ns, handle), ttl)
    }

    /// Renew a lease by id (`cache.lease_renew`). The lease must belong
    /// to the caller's namespace: lease ids are sequential (guessable),
    /// so without this check one tenant could shorten another tenant's
    /// lease to nothing. Safe against TOCTOU — lease ids are never
    /// reused, so the id→key mapping cannot change between check and act.
    pub fn cache_lease_renew(
        &self,
        ns: &Namespace,
        id: u64,
        ttl: Option<std::time::Duration>,
    ) -> Option<crate::kv::LeaseInfo> {
        match self.store.lease_key(id) {
            Some(key) if key.ns == *ns => self.store.lease_renew(id, ttl),
            _ => None,
        }
    }

    /// Release a lease by id (`cache.lease_release`), namespace-checked
    /// like [`Engine::cache_lease_renew`].
    pub fn cache_lease_release(&self, ns: &Namespace, id: u64) -> bool {
        match self.store.lease_key(id) {
            Some(key) if key.ns == *ns => self.store.lease_release(id),
            _ => false,
        }
    }

    /// A tenant's quant ceiling (the `cache.quant` read path).
    pub fn cache_quant(&self, ns: &Namespace) -> QuantLevel {
        self.store.ns_quant(ns)
    }

    /// Set a tenant's quant ceiling (`cache.quant`): the coarsest level
    /// demotion/write-through requantization may use for this
    /// namespace's entries. `QuantLevel::None` opts the tenant out of
    /// lossy tiers entirely; per-tier floors are capped by it.
    pub fn set_cache_quant(&self, ns: &Namespace, ceiling: QuantLevel) {
        self.store.set_ns_quant(ns, ceiling);
    }

    /// Evict a handle's entry from every tier. Leased entries are refused
    /// — atomically, inside the store's shard lock (see
    /// [`KvStore::evict`]), so a concurrent `cache.lease` can never lose.
    pub fn cache_evict(&self, ns: &Namespace, handle: &str) -> EvictOutcome {
        self.store.evict(&self.kv_key(ns, handle))
    }
}

/// Record the per-request `fetch` span (child `fetch.group` spans are
/// recorded by the transfer workers themselves). `stall_us`/`overlap_us`
/// are 0 for whole-entry fetches.
fn record_fetch_span(t0: Instant, rep: &TransferReport) {
    trace::record(
        "fetch",
        t0,
        &[
            ("segments", Value::num(rep.n_segments as f64)),
            ("device_hits", Value::num(rep.device_hits as f64)),
            ("host_hits", Value::num(rep.host_hits as f64)),
            ("disk_hits", Value::num(rep.disk_hits as f64)),
            ("peer_hits", Value::num(rep.peer_hits as f64)),
            ("misses", Value::num(rep.misses as f64)),
            ("stall_us", Value::num(rep.stall_us as f64)),
            ("overlap_us", Value::num(rep.overlap_us as f64)),
        ],
    );
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.0, 3.0, -1.0, 3.0]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }
}
