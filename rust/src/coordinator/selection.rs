//! Selection policies: which tokens are recomputed by each context-caching
//! algorithm, and whether the algorithm is single- or two-step.
//!
//! | algorithm    | recomputed tokens                              | steps |
//! |--------------|------------------------------------------------|-------|
//! | prefix       | everything (exact)                             | 1     |
//! | full reuse   | text only                                      | 2     |
//! | CacheBlend-r | text + top r% image tokens by KV deviation     | 2     |
//! | MPIC-k       | text + first k tokens of every image           | **1** |

use crate::mm::LinkedLayout;

/// A context-caching algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Prefix caching: recompute the whole prompt (exact baseline).
    Prefix,
    /// Full reuse: reuse every image KV verbatim, recompute text only.
    FullReuse,
    /// CacheBlend-r: additionally recompute the r% of image tokens with the
    /// largest layer-0 K deviation (r in percent of image tokens).
    CacheBlend(f64),
    /// MPIC-k: recompute the first k tokens of every image (the attention
    /// sinks — Insights 2 & 3), single-pass selective attention.
    MpicK(usize),
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::Prefix => "prefix".into(),
            Policy::FullReuse => "full-reuse".into(),
            Policy::CacheBlend(r) => format!("cacheblend-{r:.0}"),
            Policy::MpicK(k) => format!("mpic-{k}"),
        }
    }

    pub fn parse(s: &str) -> crate::Result<Policy> {
        if s == "prefix" {
            return Ok(Policy::Prefix);
        }
        if s == "full-reuse" || s == "full_reuse" {
            return Ok(Policy::FullReuse);
        }
        if let Some(r) = s.strip_prefix("cacheblend-") {
            return Ok(Policy::CacheBlend(r.parse()?));
        }
        if let Some(k) = s.strip_prefix("mpic-") {
            return Ok(Policy::MpicK(k.parse()?));
        }
        anyhow::bail!("unknown policy {s:?} (prefix|full-reuse|cacheblend-R|mpic-K)")
    }

    /// Does this policy run the two-step (text prefill, then blend) path?
    pub fn two_step(&self) -> bool {
        matches!(self, Policy::FullReuse | Policy::CacheBlend(_))
    }

    /// Does this policy need the layer-0 deviation estimate?
    pub fn needs_deviation(&self) -> bool {
        matches!(self, Policy::CacheBlend(_))
    }
}

/// The resolved plan for one request.
#[derive(Debug, Clone)]
pub struct SelectionPlan {
    pub policy: Policy,
    /// Sorted indices (linked positions) of tokens the *selective pass*
    /// recomputes. Empty for `Prefix` (which runs `prefill_full`) and for
    /// `FullReuse` (whose step 2 is a single decode-style pass).
    pub selected: Vec<usize>,
    /// Image-token indices whose stored KV rows are reused verbatim.
    pub reused: Vec<usize>,
}

/// Resolve a policy against a concrete layout.
///
/// `deviation` is the per-token layer-0 K deviation (only consulted by
/// CacheBlend; pass `&[]` otherwise). The final prompt token is always
/// selected — the first output token's logits are read from it.
pub fn plan(policy: Policy, layout: &LinkedLayout, deviation: &[f32]) -> SelectionPlan {
    let last = layout.len() - 1;
    let mut selected: Vec<usize> = match policy {
        Policy::Prefix => Vec::new(),
        Policy::FullReuse => Vec::new(),
        Policy::MpicK(k) => {
            let mut sel = layout.text_indices();
            sel.extend(layout.image_head_indices(k));
            sel
        }
        Policy::CacheBlend(r) => {
            // Step-2 selection: top r% image tokens by deviation (+ last).
            let img = layout.image_indices();
            let n_recompute = ((r / 100.0) * img.len() as f64).ceil() as usize;
            let mut scored: Vec<usize> = img;
            scored.sort_by(|&a, &b| {
                let da = deviation.get(a).copied().unwrap_or(0.0);
                let db = deviation.get(b).copied().unwrap_or(0.0);
                db.partial_cmp(&da).unwrap().then(a.cmp(&b))
            });
            scored.truncate(n_recompute);
            scored
        }
    };
    if matches!(policy, Policy::MpicK(_) | Policy::CacheBlend(_)) && !selected.contains(&last) {
        selected.push(last);
    }
    selected.sort_unstable();
    selected.dedup();

    let reused = match policy {
        Policy::Prefix => Vec::new(),
        _ => {
            let sel: std::collections::HashSet<usize> = selected.iter().copied().collect();
            layout.image_indices().into_iter().filter(|i| !sel.contains(i)).collect()
        }
    };
    SelectionPlan { policy, selected, reused }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::{ImageId, Prompt, Tokenizer, UserId};

    fn layout() -> LinkedLayout {
        let t = Tokenizer::new(4096);
        let p = Prompt::new(UserId(1))
            .text("describe the scenes")
            .image(ImageId(1))
            .image(ImageId(2))
            .text("in detail please");
        LinkedLayout::build(&p, &t, 16, "system prompt here")
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Prefix, Policy::FullReuse, Policy::CacheBlend(15.0), Policy::MpicK(32)] {
            let parsed = Policy::parse(&p.name()).unwrap();
            assert_eq!(parsed, p);
        }
        assert!(Policy::parse("bogus").is_err());
    }

    #[test]
    fn mpic_k_selects_text_and_image_heads() {
        let l = layout();
        let plan = plan(Policy::MpicK(4), &l, &[]);
        // Text + 4 per image.
        assert_eq!(plan.selected.len(), l.text_len() + 8);
        // Heads of both images are in.
        for &(_, lo, _) in &l.image_spans {
            for j in 0..4 {
                assert!(plan.selected.contains(&(lo + j)));
            }
            assert!(!plan.selected.contains(&(lo + 4)));
        }
        // Reused = all image tokens not selected.
        assert_eq!(plan.reused.len(), 32 - 8);
        // Last token always selected.
        assert!(plan.selected.contains(&(l.len() - 1)));
    }

    #[test]
    fn mpic_k_larger_than_image_is_full_recompute_of_images() {
        let l = layout();
        let plan = plan(Policy::MpicK(100), &l, &[]);
        assert_eq!(plan.selected.len(), l.len());
        assert!(plan.reused.is_empty());
    }

    #[test]
    fn cacheblend_selects_by_deviation() {
        let l = layout();
        let mut dev = vec![0.0f32; l.len()];
        let (_, lo, _) = l.image_spans[0];
        // Make tokens lo+5 and lo+9 the most deviant.
        dev[lo + 5] = 10.0;
        dev[lo + 9] = 8.0;
        let plan = plan(Policy::CacheBlend(7.0), &l, &dev); // 7% of 32 -> 3 tokens
        let img_selected: Vec<usize> =
            plan.selected.iter().copied().filter(|i| *i != l.len() - 1).collect();
        assert_eq!(img_selected.len(), 3);
        assert!(img_selected.contains(&(lo + 5)));
        assert!(img_selected.contains(&(lo + 9)));
    }

    #[test]
    fn full_reuse_reuses_every_image_token() {
        let l = layout();
        let plan = plan(Policy::FullReuse, &l, &[]);
        assert!(plan.selected.is_empty());
        assert_eq!(plan.reused.len(), 32);
    }

    #[test]
    fn prefix_recomputes_everything() {
        let l = layout();
        let plan = plan(Policy::Prefix, &l, &[]);
        assert!(plan.selected.is_empty());
        assert!(plan.reused.is_empty());
    }

    #[test]
    fn property_selected_and_reused_partition_images() {
        crate::util::prop::check(
            "selection-partition",
            40,
            |rng| {
                let k = rng.below(20) as usize;
                let n_img = 1 + rng.below(4) as usize;
                (k, n_img, rng.next_u64())
            },
            |&(k, n_img, seed)| {
                let t = Tokenizer::new(4096);
                let mut p = Prompt::new(UserId(1)).text("hello world opening");
                for i in 0..n_img {
                    p = p.image(ImageId(seed ^ i as u64)).text("and then");
                }
                let l = LinkedLayout::build(&p, &t, 16, "sys");
                let plan = plan(Policy::MpicK(k), &l, &[]);
                let img: std::collections::HashSet<usize> =
                    l.image_indices().into_iter().collect();
                for &i in &plan.reused {
                    if !img.contains(&i) {
                        return Err(format!("reused non-image token {i}"));
                    }
                    if plan.selected.contains(&i) {
                        return Err(format!("token {i} both selected and reused"));
                    }
                }
                let covered = plan.reused.len()
                    + plan.selected.iter().filter(|i| img.contains(i)).count();
                if covered != img.len() {
                    return Err("selected+reused do not cover image tokens".into());
                }
                Ok(())
            },
        );
    }
}
