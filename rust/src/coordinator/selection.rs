//! Selection policies: which tokens are recomputed by each context-caching
//! algorithm, and whether the algorithm is single- or two-step.
//!
//! Policies operate on *reusable spans* — image segments and cached text
//! chunks alike (the paper's position-independent generalisation):
//!
//! | algorithm    | recomputed tokens                                | steps |
//! |--------------|--------------------------------------------------|-------|
//! | prefix       | everything (exact)                               | 1     |
//! | full reuse   | free text only                                   | 2     |
//! | CacheBlend-r | text + top r% reused tokens by KV deviation      | 2     |
//! | MPIC-k       | text + first k tokens of every reusable span     | **1** |

use crate::mm::LinkedLayout;

/// A context-caching algorithm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Prefix caching: recompute the whole prompt (exact baseline).
    Prefix,
    /// Full reuse: reuse every segment KV verbatim, recompute text only.
    FullReuse,
    /// CacheBlend-r: additionally recompute the r% of reused tokens with
    /// the largest layer-0 K deviation (r in percent of reused tokens).
    CacheBlend(f64),
    /// MPIC-k: recompute the first k tokens of every reusable span (the
    /// attention sinks — Insights 2 & 3), single-pass selective attention.
    MpicK(usize),
}

impl Policy {
    pub fn name(&self) -> String {
        match self {
            Policy::Prefix => "prefix".into(),
            Policy::FullReuse => "full-reuse".into(),
            // `{r}` (not `{r:.0}`) so fractional ratios survive the
            // name → parse round trip: CacheBlend(7.5) must not silently
            // become CacheBlend(8.0).
            Policy::CacheBlend(r) => format!("cacheblend-{r}"),
            Policy::MpicK(k) => format!("mpic-{k}"),
        }
    }

    pub fn parse(s: &str) -> crate::Result<Policy> {
        if s == "prefix" {
            return Ok(Policy::Prefix);
        }
        if s == "full-reuse" || s == "full_reuse" {
            return Ok(Policy::FullReuse);
        }
        if let Some(r) = s.strip_prefix("cacheblend-") {
            return Ok(Policy::CacheBlend(r.parse()?));
        }
        if let Some(k) = s.strip_prefix("mpic-") {
            return Ok(Policy::MpicK(k.parse()?));
        }
        anyhow::bail!("unknown policy {s:?} (prefix|full-reuse|cacheblend-R|mpic-K)")
    }

    /// Does this policy run the two-step (text prefill, then blend) path?
    pub fn two_step(&self) -> bool {
        matches!(self, Policy::FullReuse | Policy::CacheBlend(_))
    }

    /// Does this policy need the layer-0 deviation estimate?
    pub fn needs_deviation(&self) -> bool {
        matches!(self, Policy::CacheBlend(_))
    }
}

/// The resolved plan for one request.
#[derive(Debug, Clone)]
pub struct SelectionPlan {
    pub policy: Policy,
    /// Sorted indices (linked positions) of tokens the *selective pass*
    /// recomputes. Empty for `Prefix` (which runs `prefill_full`) and for
    /// `FullReuse` (whose step 2 is a single decode-style pass).
    pub selected: Vec<usize>,
    /// Reused-token indices whose stored KV rows are spliced verbatim
    /// (image and chunk tokens not selected for recompute).
    pub reused: Vec<usize>,
}

/// Resolve a policy against a concrete layout.
///
/// `deviation` is the per-token layer-0 K deviation (only consulted by
/// CacheBlend; pass `&[]` otherwise). The final prompt token is always
/// selected — the first output token's logits are read from it.
pub fn plan(policy: Policy, layout: &LinkedLayout, deviation: &[f32]) -> SelectionPlan {
    let last = layout.len() - 1;
    let mut selected: Vec<usize> = match policy {
        Policy::Prefix => Vec::new(),
        Policy::FullReuse => Vec::new(),
        Policy::MpicK(k) => {
            let mut sel = layout.text_indices();
            sel.extend(layout.reuse_head_indices(k));
            sel
        }
        Policy::CacheBlend(r) => {
            // Step-2 selection: top r% reused tokens by deviation (+ last).
            let reuse = layout.reuse_indices();
            let n_recompute = ((r / 100.0) * reuse.len() as f64).ceil() as usize;
            let mut scored: Vec<usize> = reuse;
            // Total ordering (satellite fix): a NaN deviation — e.g. from
            // a degenerate layer-0 estimate — must not panic the sort.
            // total_cmp sorts NaNs above every finite value, so they rank
            // as "most deviant" and get recomputed, the safe direction.
            scored.sort_by(|&a, &b| {
                let da = deviation.get(a).copied().unwrap_or(0.0);
                let db = deviation.get(b).copied().unwrap_or(0.0);
                db.total_cmp(&da).then(a.cmp(&b))
            });
            scored.truncate(n_recompute);
            scored
        }
    };
    if matches!(policy, Policy::MpicK(_) | Policy::CacheBlend(_)) && !selected.contains(&last) {
        selected.push(last);
    }
    selected.sort_unstable();
    selected.dedup();

    let reused = match policy {
        Policy::Prefix => Vec::new(),
        _ => {
            let sel: std::collections::HashSet<usize> = selected.iter().copied().collect();
            layout.reuse_indices().into_iter().filter(|i| !sel.contains(i)).collect()
        }
    };
    SelectionPlan { policy, selected, reused }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::{ChunkId, ChunkRef, ImageId, Prompt, Tokenizer, UserId};

    fn layout() -> LinkedLayout {
        let t = Tokenizer::new(4096);
        let p = Prompt::new(UserId(1))
            .text("describe the scenes")
            .image(ImageId(1))
            .image(ImageId(2))
            .text("in detail please");
        LinkedLayout::build(&p, &t, 16, "system prompt here")
    }

    /// A layout mixing an image span with a cached-chunk span.
    fn mixed_layout() -> LinkedLayout {
        let t = Tokenizer::new(4096);
        let doc = t.encode("the shared festival report describes the harbour celebrations at length");
        let p = Prompt::new(UserId(1))
            .text("given")
            .chunk(ChunkRef::resolved(ChunkId(9), doc))
            .image(ImageId(1))
            .text("answer the question");
        LinkedLayout::build(&p, &t, 16, "system prompt here")
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in [Policy::Prefix, Policy::FullReuse, Policy::CacheBlend(15.0), Policy::MpicK(32)] {
            let parsed = Policy::parse(&p.name()).unwrap();
            assert_eq!(parsed, p);
        }
        assert!(Policy::parse("bogus").is_err());
    }

    /// Satellite regression: fractional CacheBlend ratios must survive the
    /// round trip. `{r:.0}` used to turn 7.5 into "cacheblend-8".
    #[test]
    fn cacheblend_fractional_ratio_roundtrips() {
        for r in [7.5, 0.25, 12.125, 15.0] {
            let p = Policy::CacheBlend(r);
            let parsed = Policy::parse(&p.name()).unwrap();
            assert_eq!(parsed, p, "name {:?} must parse back exactly", p.name());
        }
        assert_eq!(Policy::CacheBlend(7.5).name(), "cacheblend-7.5");
        assert_eq!(Policy::CacheBlend(15.0).name(), "cacheblend-15");
    }

    #[test]
    fn mpic_k_selects_text_and_span_heads() {
        let l = layout();
        let plan = plan(Policy::MpicK(4), &l, &[]);
        // Text + 4 per image.
        assert_eq!(plan.selected.len(), l.text_len() + 8);
        // Heads of both images are in.
        for span in &l.reuse_spans {
            for j in 0..4 {
                assert!(plan.selected.contains(&(span.lo + j)));
            }
            assert!(!plan.selected.contains(&(span.lo + 4)));
        }
        // Reused = all image tokens not selected.
        assert_eq!(plan.reused.len(), 32 - 8);
        // Last token always selected.
        assert!(plan.selected.contains(&(l.len() - 1)));
    }

    #[test]
    fn mpic_k_treats_chunks_like_images() {
        let l = mixed_layout();
        let chunk_span = l.reuse_spans[0];
        let img_span = l.reuse_spans[1];
        assert!(chunk_span.seg.as_chunk().is_some());
        let k = 3;
        let pl = plan(Policy::MpicK(k), &l, &[]);
        // First k tokens of BOTH spans selected, the rest reused.
        for span in [chunk_span, img_span] {
            for j in 0..k {
                assert!(pl.selected.contains(&(span.lo + j)), "head {j} of span missing");
            }
            for j in k..span.len() {
                assert!(pl.reused.contains(&(span.lo + j)), "tail {j} must be reused");
            }
        }
        // The prompt ends with text, so the always-selected last token is
        // already in the text set: no extra slot.
        assert_eq!(pl.selected.len(), l.text_len() + 2 * k);
        assert_eq!(pl.reused.len(), chunk_span.len() + img_span.len() - 2 * k);
    }

    #[test]
    fn mpic_k_larger_than_image_is_full_recompute_of_images() {
        let l = layout();
        let plan = plan(Policy::MpicK(100), &l, &[]);
        assert_eq!(plan.selected.len(), l.len());
        assert!(plan.reused.is_empty());
    }

    #[test]
    fn cacheblend_selects_by_deviation() {
        let l = layout();
        let mut dev = vec![0.0f32; l.len()];
        let lo = l.reuse_spans[0].lo;
        // Make tokens lo+5 and lo+9 the most deviant.
        dev[lo + 5] = 10.0;
        dev[lo + 9] = 8.0;
        let plan = plan(Policy::CacheBlend(7.0), &l, &dev); // 7% of 32 -> 3 tokens
        let img_selected: Vec<usize> =
            plan.selected.iter().copied().filter(|i| *i != l.len() - 1).collect();
        assert_eq!(img_selected.len(), 3);
        assert!(img_selected.contains(&(lo + 5)));
        assert!(img_selected.contains(&(lo + 9)));
    }

    /// Satellite regression: a NaN deviation must not panic the sort, and
    /// ranks as most-deviant (recomputed) under the total order.
    #[test]
    fn cacheblend_survives_nan_deviation() {
        let l = layout();
        let mut dev = vec![0.0f32; l.len()];
        let lo = l.reuse_spans[0].lo;
        dev[lo + 2] = f32::NAN;
        dev[lo + 7] = 5.0;
        let plan = plan(Policy::CacheBlend(7.0), &l, &dev); // 3 tokens
        let img_selected: Vec<usize> =
            plan.selected.iter().copied().filter(|i| *i != l.len() - 1).collect();
        assert_eq!(img_selected.len(), 3);
        assert!(img_selected.contains(&(lo + 2)), "NaN must rank as most deviant");
        assert!(img_selected.contains(&(lo + 7)));
        // All-NaN deviations: still no panic, still the exact budget.
        let all_nan = vec![f32::NAN; l.len()];
        let pl2 = plan2(&l, &all_nan);
        assert_eq!(
            pl2.selected.iter().filter(|&&i| i != l.len() - 1).count(),
            3
        );
    }

    fn plan2(l: &LinkedLayout, dev: &[f32]) -> SelectionPlan {
        plan(Policy::CacheBlend(7.0), l, dev)
    }

    #[test]
    fn full_reuse_reuses_every_segment_token() {
        let l = layout();
        let plan = plan(Policy::FullReuse, &l, &[]);
        assert!(plan.selected.is_empty());
        assert_eq!(plan.reused.len(), 32);
        // Chunk tokens are reused verbatim too.
        let m = mixed_layout();
        let pl = super::plan(Policy::FullReuse, &m, &[]);
        assert_eq!(pl.reused.len(), m.reuse_indices().len());
    }

    #[test]
    fn prefix_recomputes_everything() {
        let l = layout();
        let plan = plan(Policy::Prefix, &l, &[]);
        assert!(plan.selected.is_empty());
        assert!(plan.reused.is_empty());
    }

    #[test]
    fn property_selected_and_reused_partition_segments() {
        crate::util::prop::check(
            "selection-partition",
            40,
            |rng| {
                let k = rng.below(20) as usize;
                let n_seg = 1 + rng.below(4) as usize;
                (k, n_seg, rng.next_u64())
            },
            |&(k, n_seg, seed)| {
                let t = Tokenizer::new(4096);
                let mut p = Prompt::new(UserId(1)).text("hello world opening");
                for i in 0..n_seg {
                    // Alternate image and chunk segments so the partition
                    // invariant covers both reusable kinds.
                    if i % 2 == 0 {
                        p = p.image(ImageId(seed ^ i as u64)).text("and then");
                    } else {
                        let doc = t.encode("some shared reference words here");
                        p = p
                            .chunk(ChunkRef::resolved(ChunkId(seed ^ i as u64), doc))
                            .text("and then");
                    }
                }
                let l = LinkedLayout::build(&p, &t, 16, "sys");
                let plan = plan(Policy::MpicK(k), &l, &[]);
                let reuse: std::collections::HashSet<usize> =
                    l.reuse_indices().into_iter().collect();
                for &i in &plan.reused {
                    if !reuse.contains(&i) {
                        return Err(format!("reused non-segment token {i}"));
                    }
                    if plan.selected.contains(&i) {
                        return Err(format!("token {i} both selected and reused"));
                    }
                }
                let covered = plan.reused.len()
                    + plan.selected.iter().filter(|i| reuse.contains(i)).count();
                if covered != reuse.len() {
                    return Err("selected+reused do not cover segment tokens".into());
                }
                Ok(())
            },
        );
    }
}
