//! Deterministic word-hash tokenizer.
//!
//! The reproduction does not need linguistic fidelity — only a stable,
//! injective-enough mapping from text to ids in `[RESERVED, vocab)` so that
//! identical text always produces identical token streams (cache keys,
//! quality scoring) and different text (almost always) differs.

/// Ids 0..RESERVED are reserved: 0 = PAD, 1 = BOS, 2 = EOS, 3..10 spare.
pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const RESERVED: i32 = 10;

/// Word-level hash tokenizer over a fixed vocabulary size.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: i32,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        assert!(vocab as i32 > RESERVED + 1, "vocab too small");
        Tokenizer { vocab: vocab as i32 }
    }

    pub fn vocab(&self) -> usize {
        self.vocab as usize
    }

    /// Tokenize one word (case-normalised, punctuation-stripped).
    pub fn word_id(&self, word: &str) -> i32 {
        let norm: String = word
            .chars()
            .filter(|c| c.is_alphanumeric())
            .flat_map(|c| c.to_lowercase())
            .collect();
        let h = crate::util::rng::fnv1a(norm.as_bytes());
        RESERVED + (h % (self.vocab - RESERVED) as u64) as i32
    }

    /// Tokenize a text span to ids (whitespace word split; punctuation
    /// marks double as their own tokens to lengthen realistic prompts).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for word in text.split_whitespace() {
            let core: String = word.chars().filter(|c| c.is_alphanumeric()).collect();
            if !core.is_empty() {
                out.push(self.word_id(&core));
            }
            for c in word.chars().filter(|c| ",.;:!?".contains(*c)) {
                out.push(self.word_id(&c.to_string()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_and_case_insensitive() {
        let t = Tokenizer::new(4096);
        assert_eq!(t.encode("Hello world"), t.encode("hello  WORLD"));
    }

    #[test]
    fn punctuation_tokens() {
        let t = Tokenizer::new(4096);
        let with = t.encode("hello, world.");
        let without = t.encode("hello world");
        assert_eq!(with.len(), 4);
        assert_eq!(without.len(), 2);
    }

    #[test]
    fn ids_in_range() {
        let t = Tokenizer::new(4096);
        for id in t.encode("the quick brown fox jumps over the lazy dog, twice!") {
            assert!((RESERVED..4096).contains(&id));
        }
    }

    #[test]
    fn different_words_usually_differ() {
        let t = Tokenizer::new(4096);
        let ids: std::collections::HashSet<i32> = ["alpha", "beta", "gamma", "delta", "epsilon"]
            .iter()
            .map(|w| t.word_id(w))
            .collect();
        assert!(ids.len() >= 4);
    }
}
