//! Linked-sequence layout: token-level view of a multimodal prompt.
//!
//! "Linked" is the paper's linker metaphor: each token of the prompt —
//! text, image or cached chunk — is assigned a *linked position* (its true
//! position in the final sequence) and a *cache slot* (where its KV row
//! lives in the bucketed cache tensor). For this layout slots equal
//! positions; the bucket padding beyond `len()` is the slack the selective
//! artifacts mask out.
//!
//! Reusable segments (images *and* chunks) are recorded as
//! [`ReuseSpan`]s: the `[lo, hi)` slot ranges whose KV rows can be spliced
//! from the store instead of recomputed.

use super::tokenizer::{Tokenizer, BOS};
use super::{ChunkId, ImageId, Prompt, Segment, SegmentId};

/// What occupies one linked slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TokenKind {
    /// Free text token with its vocabulary id (always recomputed).
    Text(i32),
    /// The `rel`-th token of image `id`.
    Image { id: ImageId, rel: u32 },
    /// The `rel`-th token of cached chunk `id`, with its vocabulary id
    /// (needed when a selection policy recomputes it).
    Chunk { id: ChunkId, rel: u32, tok: i32 },
}

/// `[lo, hi)` slot range of one reusable segment, in prompt order
/// (repeats allowed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReuseSpan {
    pub seg: SegmentId,
    pub lo: usize,
    pub hi: usize,
}

impl ReuseSpan {
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }
}

/// Token-level layout of one prompt.
#[derive(Debug, Clone)]
pub struct LinkedLayout {
    /// Real tokens in linked order; index == linked position == cache slot.
    pub tokens: Vec<TokenKind>,
    /// Reusable-segment spans (images and chunks), in prompt order.
    pub reuse_spans: Vec<ReuseSpan>,
    /// Length of the leading system-prompt span (incl. BOS).
    pub sys_len: usize,
}

impl LinkedLayout {
    /// Lay out `[BOS] system_prompt segments...`. Chunk segments must be
    /// *resolved* (carry their canonical tokens) — the engine resolves
    /// handles against its chunk registry before building the layout.
    pub fn build(
        prompt: &Prompt,
        tokenizer: &Tokenizer,
        img_tokens: usize,
        system_prompt: &str,
    ) -> LinkedLayout {
        let mut tokens = vec![TokenKind::Text(BOS)];
        for id in tokenizer.encode(system_prompt) {
            tokens.push(TokenKind::Text(id));
        }
        let sys_len = tokens.len();

        let mut reuse_spans = Vec::new();
        for seg in &prompt.segments {
            match seg {
                Segment::Text(s) => {
                    for id in tokenizer.encode(s) {
                        tokens.push(TokenKind::Text(id));
                    }
                }
                Segment::Image(id) => {
                    let lo = tokens.len();
                    for rel in 0..img_tokens {
                        tokens.push(TokenKind::Image { id: *id, rel: rel as u32 });
                    }
                    reuse_spans.push(ReuseSpan {
                        seg: SegmentId::Image(*id),
                        lo,
                        hi: tokens.len(),
                    });
                }
                Segment::Chunk(c) => {
                    let lo = tokens.len();
                    for (rel, tok) in c.tokens.iter().enumerate() {
                        tokens.push(TokenKind::Chunk { id: c.id, rel: rel as u32, tok: *tok });
                    }
                    reuse_spans.push(ReuseSpan {
                        seg: SegmentId::Chunk(c.id),
                        lo,
                        hi: tokens.len(),
                    });
                }
            }
        }
        LinkedLayout { tokens, reuse_spans, sys_len }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Kind codes padded to `bucket`: 0 pad, 1 text, 2 image (mirrors
    /// `model.make_sink_bias`). Chunk tokens are text content, so they
    /// take the text code — exactly what their canonical prefill saw.
    pub fn kinds(&self, bucket: usize) -> Vec<u8> {
        let mut out = vec![0u8; bucket];
        for (i, t) in self.tokens.iter().enumerate().take(bucket) {
            out[i] = match t {
                TokenKind::Text(_) | TokenKind::Chunk { .. } => 1,
                TokenKind::Image { .. } => 2,
            };
        }
        out
    }

    /// Intra-image relative positions padded to `bucket`.
    pub fn img_rel(&self, bucket: usize) -> Vec<u32> {
        let mut out = vec![0u32; bucket];
        for (i, t) in self.tokens.iter().enumerate().take(bucket) {
            if let TokenKind::Image { rel, .. } = t {
                out[i] = *rel;
            }
        }
        out
    }

    /// Indices of all free-text tokens (the always-recompute set). Chunk
    /// tokens are *not* free text: their KV is reusable.
    pub fn text_indices(&self) -> Vec<usize> {
        self.tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, TokenKind::Text(_)))
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices of the first `k` tokens of every reuse span (MPIC-k: the
    /// attention-sink heads of images *and* chunks are recomputed).
    pub fn reuse_head_indices(&self, k: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for span in &self.reuse_spans {
            out.extend(span.lo..span.hi.min(span.lo + k));
        }
        out
    }

    /// All reusable-segment token indices (image and chunk tokens).
    pub fn reuse_indices(&self) -> Vec<usize> {
        self.tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| !matches!(t, TokenKind::Text(_)))
            .map(|(i, _)| i)
            .collect()
    }

    /// Token count contributed by free text (incl. BOS/system prompt).
    pub fn text_len(&self) -> usize {
        self.text_indices().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::{ChunkRef, UserId};

    fn layout(prompt: &Prompt) -> LinkedLayout {
        let t = Tokenizer::new(4096);
        LinkedLayout::build(prompt, &t, 8, "you are a helpful assistant")
    }

    #[test]
    fn layout_structure() {
        let p = Prompt::new(UserId(1))
            .text("look at")
            .image(ImageId(10))
            .text("and")
            .image(ImageId(11))
            .text("compare them");
        let l = layout(&p);
        assert_eq!(l.reuse_spans.len(), 2);
        assert_eq!(l.sys_len, 6); // BOS + 5 words
        let span0 = l.reuse_spans[0];
        assert_eq!(span0.seg, SegmentId::Image(ImageId(10)));
        assert_eq!(span0.len(), 8);
        // Text before first image: sys + "look at".
        assert_eq!(span0.lo, 6 + 2);
        assert!(matches!(l.tokens[0], TokenKind::Text(BOS)));
    }

    #[test]
    fn kinds_and_rel() {
        let p = Prompt::new(UserId(1)).text("a").image(ImageId(3));
        let l = layout(&p);
        let bucket = 32;
        let kinds = l.kinds(bucket);
        let rel = l.img_rel(bucket);
        let span = l.reuse_spans[0];
        assert!(kinds[..span.lo].iter().all(|&k| k == 1));
        assert!(kinds[span.lo..span.hi].iter().all(|&k| k == 2));
        assert!(kinds[span.hi..].iter().all(|&k| k == 0));
        assert_eq!(rel[span.lo], 0);
        assert_eq!(rel[span.hi - 1], 7);
    }

    #[test]
    fn selection_helpers() {
        let p = Prompt::new(UserId(1)).text("x y").image(ImageId(1)).image(ImageId(2)).text("z");
        let l = layout(&p);
        let text = l.text_indices();
        let heads = l.reuse_head_indices(3);
        assert_eq!(heads.len(), 6);
        assert_eq!(l.reuse_indices().len(), 16);
        assert_eq!(text.len() + 16, l.len());
        // Heads are the first 3 of each span.
        assert_eq!(heads[0], l.reuse_spans[0].lo);
        assert_eq!(heads[3], l.reuse_spans[1].lo);
    }

    #[test]
    fn same_image_twice_gets_two_spans() {
        let p = Prompt::new(UserId(1)).image(ImageId(7)).text("mid").image(ImageId(7));
        let l = layout(&p);
        assert_eq!(l.reuse_spans.len(), 2);
        assert_eq!(l.reuse_spans[0].seg, l.reuse_spans[1].seg);
        assert_ne!(l.reuse_spans[0].lo, l.reuse_spans[1].lo);
    }

    #[test]
    fn chunk_segments_get_reuse_spans_with_token_ids() {
        let t = Tokenizer::new(4096);
        let toks = t.encode("shared reference document about the harbour festival");
        let n = toks.len();
        let p = Prompt::new(UserId(1))
            .text("using")
            .chunk(ChunkRef::resolved(ChunkId(5), toks.clone()))
            .text("answer this")
            .image(ImageId(9));
        let l = layout(&p);
        assert_eq!(l.reuse_spans.len(), 2);
        let chunk_span = l.reuse_spans[0];
        assert_eq!(chunk_span.seg, SegmentId::Chunk(ChunkId(5)));
        assert_eq!(chunk_span.len(), n);
        // Chunk tokens carry their canonical vocab ids and relative
        // positions, and count as kind=1 (text) for the sink bias.
        for (rel, slot) in (chunk_span.lo..chunk_span.hi).enumerate() {
            match l.tokens[slot] {
                TokenKind::Chunk { id, rel: r, tok } => {
                    assert_eq!(id, ChunkId(5));
                    assert_eq!(r as usize, rel);
                    assert_eq!(tok, toks[rel]);
                }
                other => panic!("slot {slot} is {other:?}, expected chunk token"),
            }
        }
        let kinds = l.kinds(l.len());
        assert!(kinds[chunk_span.lo..chunk_span.hi].iter().all(|&k| k == 1));
        // Chunk tokens are reusable, not free text.
        assert!(l.text_indices().iter().all(|&i| i < chunk_span.lo || i >= chunk_span.hi));
        assert_eq!(l.reuse_indices().len(), n + 8);
        // MPIC-k heads cover the chunk head too.
        let heads = l.reuse_head_indices(2);
        assert!(heads.contains(&chunk_span.lo));
        assert!(heads.contains(&(chunk_span.lo + 1)));
        assert_eq!(heads.len(), 4);
    }
}
