//! Linked-sequence layout: token-level view of a multimodal prompt.
//!
//! "Linked" is the paper's linker metaphor: each token of the prompt —
//! text or image — is assigned a *linked position* (its true position in
//! the final sequence) and a *cache slot* (where its KV row lives in the
//! bucketed cache tensor). For this layout slots equal positions; the
//! bucket padding beyond `len()` is the slack the selective artifacts mask
//! out.

use super::tokenizer::{Tokenizer, BOS};
use super::{ImageId, Prompt, Segment};

/// What occupies one linked slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TokenKind {
    /// Text token with its vocabulary id.
    Text(i32),
    /// The `rel`-th token of image `id`.
    Image { id: ImageId, rel: u32 },
}

/// Token-level layout of one prompt.
#[derive(Debug, Clone)]
pub struct LinkedLayout {
    /// Real tokens in linked order; index == linked position == cache slot.
    pub tokens: Vec<TokenKind>,
    /// `[lo, hi)` span of every image, in prompt order (repeats allowed).
    pub image_spans: Vec<(ImageId, usize, usize)>,
    /// Length of the leading system-prompt span (incl. BOS).
    pub sys_len: usize,
}

impl LinkedLayout {
    /// Lay out `[BOS] system_prompt segments...`.
    pub fn build(
        prompt: &Prompt,
        tokenizer: &Tokenizer,
        img_tokens: usize,
        system_prompt: &str,
    ) -> LinkedLayout {
        let mut tokens = vec![TokenKind::Text(BOS)];
        for id in tokenizer.encode(system_prompt) {
            tokens.push(TokenKind::Text(id));
        }
        let sys_len = tokens.len();

        let mut image_spans = Vec::new();
        for seg in &prompt.segments {
            match seg {
                Segment::Text(s) => {
                    for id in tokenizer.encode(s) {
                        tokens.push(TokenKind::Text(id));
                    }
                }
                Segment::Image(id) => {
                    let lo = tokens.len();
                    for rel in 0..img_tokens {
                        tokens.push(TokenKind::Image { id: *id, rel: rel as u32 });
                    }
                    image_spans.push((*id, lo, tokens.len()));
                }
            }
        }
        LinkedLayout { tokens, image_spans, sys_len }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Kind codes padded to `bucket`: 0 pad, 1 text, 2 image (mirrors
    /// `model.make_sink_bias`).
    pub fn kinds(&self, bucket: usize) -> Vec<u8> {
        let mut out = vec![0u8; bucket];
        for (i, t) in self.tokens.iter().enumerate().take(bucket) {
            out[i] = match t {
                TokenKind::Text(_) => 1,
                TokenKind::Image { .. } => 2,
            };
        }
        out
    }

    /// Intra-image relative positions padded to `bucket`.
    pub fn img_rel(&self, bucket: usize) -> Vec<u32> {
        let mut out = vec![0u32; bucket];
        for (i, t) in self.tokens.iter().enumerate().take(bucket) {
            if let TokenKind::Image { rel, .. } = t {
                out[i] = *rel;
            }
        }
        out
    }

    /// Indices of all text tokens (the always-recompute set).
    pub fn text_indices(&self) -> Vec<usize> {
        self.tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, TokenKind::Text(_)))
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices of the first `k` tokens of every image span (MPIC-k).
    pub fn image_head_indices(&self, k: usize) -> Vec<usize> {
        let mut out = Vec::new();
        for &(_, lo, hi) in &self.image_spans {
            out.extend(lo..hi.min(lo + k));
        }
        out
    }

    /// All image-token indices.
    pub fn image_indices(&self) -> Vec<usize> {
        self.tokens
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t, TokenKind::Image { .. }))
            .map(|(i, _)| i)
            .collect()
    }

    /// Token count contributed by text (incl. BOS/system prompt).
    pub fn text_len(&self) -> usize {
        self.text_indices().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::UserId;

    fn layout(prompt: &Prompt) -> LinkedLayout {
        let t = Tokenizer::new(4096);
        LinkedLayout::build(prompt, &t, 8, "you are a helpful assistant")
    }

    #[test]
    fn layout_structure() {
        let p = Prompt::new(UserId(1))
            .text("look at")
            .image(ImageId(10))
            .text("and")
            .image(ImageId(11))
            .text("compare them");
        let l = layout(&p);
        assert_eq!(l.image_spans.len(), 2);
        assert_eq!(l.sys_len, 6); // BOS + 5 words
        let (id0, lo0, hi0) = l.image_spans[0];
        assert_eq!(id0, ImageId(10));
        assert_eq!(hi0 - lo0, 8);
        // Text before first image: sys + "look at".
        assert_eq!(lo0, 6 + 2);
        assert!(matches!(l.tokens[0], TokenKind::Text(BOS)));
    }

    #[test]
    fn kinds_and_rel() {
        let p = Prompt::new(UserId(1)).text("a").image(ImageId(3));
        let l = layout(&p);
        let bucket = 32;
        let kinds = l.kinds(bucket);
        let rel = l.img_rel(bucket);
        let (_, lo, hi) = l.image_spans[0];
        assert!(kinds[..lo].iter().all(|&k| k == 1));
        assert!(kinds[lo..hi].iter().all(|&k| k == 2));
        assert!(kinds[hi..].iter().all(|&k| k == 0));
        assert_eq!(rel[lo], 0);
        assert_eq!(rel[hi - 1], 7);
    }

    #[test]
    fn selection_helpers() {
        let p = Prompt::new(UserId(1)).text("x y").image(ImageId(1)).image(ImageId(2)).text("z");
        let l = layout(&p);
        let text = l.text_indices();
        let heads = l.image_head_indices(3);
        assert_eq!(heads.len(), 6);
        assert_eq!(l.image_indices().len(), 16);
        assert_eq!(text.len() + 16, l.len());
        // Heads are the first 3 of each span.
        assert_eq!(heads[0], l.image_spans[0].1);
        assert_eq!(heads[3], l.image_spans[1].1);
    }

    #[test]
    fn same_image_twice_gets_two_spans() {
        let p = Prompt::new(UserId(1)).image(ImageId(7)).text("mid").image(ImageId(7));
        let l = layout(&p);
        assert_eq!(l.image_spans.len(), 2);
        assert_eq!(l.image_spans[0].0, l.image_spans[1].0);
        assert_ne!(l.image_spans[0].1, l.image_spans[1].1);
    }
}
