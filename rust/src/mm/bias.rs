//! Sink-bias construction — the Rust mirror of
//! `python/compile/model.py::make_sink_bias`.
//!
//! The bias is part of the model (every attention path applies it); the
//! Linker computes it per request from the prompt's segment structure and
//! ships it as the `sink_bias` activation input. Keeping the two
//! implementations in lockstep is verified end-to-end by the runtime
//! integration tests (stored-KV vs prefill equivalence only holds if the
//! bias agrees).

/// Parameters of the sink calibration (from the model manifest).
#[derive(Debug, Clone, Copy)]
pub struct SinkParams {
    pub sigma: f32,
    pub tau: f32,
    pub bos: f32,
}

/// Build the per-slot bias. `kinds`: 0 pad / 1 text / 2 image;
/// `img_rel`: intra-image relative position (0 where not an image token).
pub fn make_sink_bias(p: SinkParams, kinds: &[u8], img_rel: &[u32]) -> Vec<f32> {
    assert_eq!(kinds.len(), img_rel.len());
    let mut bias = vec![0.0f32; kinds.len()];
    for i in 0..kinds.len() {
        if kinds[i] == 2 {
            bias[i] = p.sigma * (-(img_rel[i] as f32) / p.tau).exp();
        }
    }
    if !kinds.is_empty() && kinds[0] != 0 {
        bias[0] += p.bos;
    }
    bias
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: SinkParams = SinkParams { sigma: 3.0, tau: 8.0, bos: 2.0 };

    #[test]
    fn matches_python_reference_values() {
        // Mirrors python/tests/test_model.py::TestSinkBias::test_structure.
        let kinds = [1u8, 1, 2, 2, 2, 1, 0];
        let rel = [0u32, 0, 0, 1, 2, 0, 0];
        let b = make_sink_bias(P, &kinds, &rel);
        assert!((b[0] - 2.0).abs() < 1e-6);
        assert!((b[2] - 3.0).abs() < 1e-6);
        assert!(b[2] > b[3] && b[3] > b[4] && b[4] > 0.0);
        assert_eq!(b[5], 0.0);
        assert_eq!(b[6], 0.0);
    }

    #[test]
    fn pad_leading_slot_gets_no_bos() {
        let b = make_sink_bias(P, &[0, 1], &[0, 0]);
        assert_eq!(b[0], 0.0);
    }

    #[test]
    fn decay_shape() {
        let kinds = vec![2u8; 64];
        let rel: Vec<u32> = (0..64).collect();
        let b = make_sink_bias(P, &kinds, &rel);
        // Monotone decay after slot 0 (which also has BOS).
        for i in 2..64 {
            assert!(b[i] < b[i - 1]);
        }
        // Half the mass is gone within ~tau*ln2 tokens.
        assert!(b[8] < 3.0 * 0.5 + 2.0);
    }
}
