//! Multimodal prompt model (substrate S8).
//!
//! A prompt is a sequence of [`Segment`]s — text spans and image
//! references — exactly the interleaved structure of paper Fig. 1. This
//! module tokenizes text deterministically, lays the prompt out as a
//! *linked sequence* (every token gets a linked position and a cache slot),
//! and builds the per-key sink-bias vector (mirroring
//! `python/compile/model.py::make_sink_bias`).

pub mod bias;
pub mod layout;
pub mod tokenizer;

pub use bias::make_sink_bias;
pub use layout::{LinkedLayout, TokenKind};
pub use tokenizer::Tokenizer;

/// Stable identifier of an uploaded or retrieved image.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ImageId(pub u64);

impl ImageId {
    /// Derive an id from a human-readable handle, e.g. `IMAGE#EIFFEL2025`.
    pub fn from_handle(handle: &str) -> ImageId {
        ImageId(crate::util::rng::fnv1a(handle.as_bytes()))
    }
}

/// Stable identifier of a user (Static Library namespace).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct UserId(pub u64);

/// One piece of an interleaved multimodal prompt.
#[derive(Debug, Clone, PartialEq)]
pub enum Segment {
    Text(String),
    Image(ImageId),
}

/// A full multimodal prompt.
#[derive(Debug, Clone)]
pub struct Prompt {
    pub user: UserId,
    pub segments: Vec<Segment>,
}

impl Prompt {
    pub fn new(user: UserId) -> Prompt {
        Prompt { user, segments: Vec::new() }
    }

    pub fn text(mut self, s: &str) -> Prompt {
        self.segments.push(Segment::Text(s.to_string()));
        self
    }

    pub fn image(mut self, id: ImageId) -> Prompt {
        self.segments.push(Segment::Image(id));
        self
    }

    pub fn images(&self) -> Vec<ImageId> {
        self.segments
            .iter()
            .filter_map(|s| match s {
                Segment::Image(id) => Some(*id),
                _ => None,
            })
            .collect()
    }

    /// Parse the `IMAGE#HANDLE` convention out of a flat string, mirroring
    /// the paper's Fig. 1 dialogues: words starting with `IMAGE#` become
    /// image segments, everything else stays text.
    pub fn parse(user: UserId, s: &str) -> Prompt {
        let mut p = Prompt::new(user);
        let mut text_run: Vec<&str> = Vec::new();
        for word in s.split_whitespace() {
            let trimmed = word.trim_matches(|c: char| ",.;:!?".contains(c));
            if let Some(_handle) = trimmed.strip_prefix("IMAGE#") {
                if !text_run.is_empty() {
                    p.segments.push(Segment::Text(text_run.join(" ")));
                    text_run.clear();
                }
                p.segments.push(Segment::Image(ImageId::from_handle(trimmed)));
            } else {
                text_run.push(word);
            }
        }
        if !text_run.is_empty() {
            p.segments.push(Segment::Text(text_run.join(" ")));
        }
        p
    }
}

/// Synthesise deterministic "pixel" patch features for an image id.
///
/// Stands in for real image bytes (DESIGN.md §2): the vision encoder only
/// needs a deterministic, id-unique input tensor of shape
/// `[img_tokens, patch_dim]`.
pub fn synth_patches(id: ImageId, img_tokens: usize, patch_dim: usize) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(id.0 ^ 0x494D4147); // "IMAG"
    (0..img_tokens * patch_dim).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_interleaved() {
        let p = Prompt::parse(
            UserId(1),
            "My partner and I took these photos IMAGE#EIFFEL2025 IMAGE#LOUVRE2025 please describe them",
        );
        assert_eq!(p.images().len(), 2);
        assert!(matches!(p.segments[0], Segment::Text(_)));
        assert!(matches!(p.segments[1], Segment::Image(_)));
        assert!(matches!(p.segments[3], Segment::Text(_)));
    }

    #[test]
    fn parse_strips_punctuation_from_handles() {
        let p = Prompt::parse(UserId(1), "link IMAGE#A, and IMAGE#B.");
        assert_eq!(p.images(), vec![ImageId::from_handle("IMAGE#A"), ImageId::from_handle("IMAGE#B")]);
    }

    #[test]
    fn image_id_stable() {
        assert_eq!(ImageId::from_handle("IMAGE#X"), ImageId::from_handle("IMAGE#X"));
        assert_ne!(ImageId::from_handle("IMAGE#X"), ImageId::from_handle("IMAGE#Y"));
    }

    #[test]
    fn synth_patches_deterministic_and_unique() {
        let a = synth_patches(ImageId(5), 8, 4);
        let b = synth_patches(ImageId(5), 8, 4);
        let c = synth_patches(ImageId(6), 8, 4);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 32);
    }
}
