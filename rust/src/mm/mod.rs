//! Multimodal prompt model (substrate S8).
//!
//! A prompt is a sequence of [`Segment`]s — text spans, image references
//! and *cached text chunks* (RAG documents, repeated boilerplate) — the
//! interleaved structure of paper Fig. 1 extended to the MRAG workloads of
//! §4.2. This module tokenizes text deterministically, lays the prompt out
//! as a *linked sequence* (every token gets a linked position and a cache
//! slot), and builds the per-key sink-bias vector (mirroring
//! `python/compile/model.py::make_sink_bias`).
//!
//! Images and chunks are both **position-independent reusable segments**
//! ([`SegmentId`]): their KV is computed once at canonical positions
//! `0..n` and spliced at whatever linked positions a prompt places them.

pub mod bias;
pub mod layout;
pub mod tokenizer;

pub use bias::make_sink_bias;
pub use layout::{LinkedLayout, ReuseSpan, TokenKind};
pub use tokenizer::Tokenizer;

/// A tenant namespace (the v3 `"ns"` envelope field).
///
/// Every cache key, registry record and session is scoped by a namespace,
/// so two tenants uploading `IMAGE#LOGO` get distinct entries and
/// `cache.list` only shows the caller's own state. The **default**
/// namespace (empty string) is the pre-v3 world: requests that carry no
/// `"ns"` field see exactly the behaviour the v1/v2 protocol had.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord, Default)]
pub struct Namespace(String);

impl Namespace {
    /// Parse and validate a namespace name: 1–64 chars of `[A-Za-z0-9._-]`
    /// (the charset keeps disk-tier file stems and wire fields safe).
    pub fn new(s: &str) -> crate::Result<Namespace> {
        anyhow::ensure!(
            !s.is_empty() && s.len() <= 64,
            "namespace must be 1..=64 characters (got {})",
            s.len()
        );
        anyhow::ensure!(
            s.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-')),
            "namespace {s:?} may only contain [A-Za-z0-9._-]"
        );
        Ok(Namespace(s.to_string()))
    }

    /// The default (pre-v3) namespace.
    pub fn root() -> Namespace {
        Namespace::default()
    }

    pub fn is_default(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl std::fmt::Display for Namespace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0.is_empty() {
            f.write_str("(default)")
        } else {
            f.write_str(&self.0)
        }
    }
}

/// Stable identifier of an uploaded or retrieved image.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ImageId(pub u64);

impl ImageId {
    /// Derive an id from a human-readable handle, e.g. `IMAGE#EIFFEL2025`.
    pub fn from_handle(handle: &str) -> ImageId {
        ImageId(crate::util::rng::fnv1a(handle.as_bytes()))
    }
}

/// Stable identifier of an uploaded text chunk (a RAG document, a shared
/// context block). Content-addressed from its handle, like [`ImageId`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub u64);

impl ChunkId {
    /// Derive an id from a human-readable handle, e.g. `CHUNK#DOC1`.
    pub fn from_handle(handle: &str) -> ChunkId {
        ChunkId(crate::util::rng::fnv1a(handle.as_bytes()))
    }
}

/// A position-independent reusable segment: the unit the KV cache stores,
/// fetches and splices. Image KV comes from the vision encoder; chunk KV
/// comes from a canonical text-only prefill at positions `0..n`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SegmentId {
    Image(ImageId),
    Chunk(ChunkId),
}

impl SegmentId {
    /// The raw 64-bit identity (unique only within a kind).
    pub fn raw(&self) -> u64 {
        match self {
            SegmentId::Image(id) => id.0,
            SegmentId::Chunk(id) => id.0,
        }
    }

    /// One-byte kind discriminant (stable across the codec/store).
    pub fn kind_tag(&self) -> u8 {
        match self {
            SegmentId::Image(_) => b'i',
            SegmentId::Chunk(_) => b'c',
        }
    }

    pub fn kind_str(&self) -> &'static str {
        match self {
            SegmentId::Image(_) => "image",
            SegmentId::Chunk(_) => "chunk",
        }
    }

    pub fn as_image(&self) -> Option<ImageId> {
        match self {
            SegmentId::Image(id) => Some(*id),
            SegmentId::Chunk(_) => None,
        }
    }

    pub fn as_chunk(&self) -> Option<ChunkId> {
        match self {
            SegmentId::Chunk(id) => Some(*id),
            SegmentId::Image(_) => None,
        }
    }
}

/// Stable identifier of a user (Static Library namespace).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct UserId(pub u64);

/// A reference to a cached text chunk inside a prompt.
///
/// `tokens` is the chunk's canonical token stream, shared behind an `Arc`
/// so resolving/cloning a reference on the serving hot path is a refcount
/// bump, not an O(tokens) copy. References built from handles (e.g. by
/// [`Prompt::parse`]) start *unresolved* (empty tokens); the engine
/// resolves them against its chunk registry before layout, so the linked
/// layout always sees the canonical token count.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkRef {
    pub id: ChunkId,
    pub tokens: std::sync::Arc<Vec<i32>>,
}

impl ChunkRef {
    pub fn unresolved(id: ChunkId) -> ChunkRef {
        ChunkRef { id, tokens: std::sync::Arc::new(Vec::new()) }
    }

    pub fn resolved(id: ChunkId, tokens: Vec<i32>) -> ChunkRef {
        ChunkRef { id, tokens: std::sync::Arc::new(tokens) }
    }

    /// Resolve from an already-shared stream (the chunk registry's copy).
    pub fn resolved_shared(id: ChunkId, tokens: std::sync::Arc<Vec<i32>>) -> ChunkRef {
        ChunkRef { id, tokens }
    }

    pub fn is_resolved(&self) -> bool {
        !self.tokens.is_empty()
    }
}

/// One piece of an interleaved multimodal prompt.
#[derive(Debug, Clone, PartialEq)]
pub enum Segment {
    Text(String),
    Image(ImageId),
    /// A cached text chunk, reused position-independently like an image.
    Chunk(ChunkRef),
}

/// A full multimodal prompt.
#[derive(Debug, Clone)]
pub struct Prompt {
    pub user: UserId,
    /// Tenant namespace the request runs in: scopes every cache key,
    /// registry lookup and session this prompt touches. Defaults to the
    /// root namespace (pre-v3 behaviour).
    pub ns: Namespace,
    pub segments: Vec<Segment>,
}

impl Prompt {
    pub fn new(user: UserId) -> Prompt {
        Prompt { user, ns: Namespace::default(), segments: Vec::new() }
    }

    /// Scope the prompt to a tenant namespace.
    pub fn in_ns(mut self, ns: &Namespace) -> Prompt {
        self.ns = ns.clone();
        self
    }

    pub fn text(mut self, s: &str) -> Prompt {
        self.segments.push(Segment::Text(s.to_string()));
        self
    }

    pub fn image(mut self, id: ImageId) -> Prompt {
        self.segments.push(Segment::Image(id));
        self
    }

    pub fn chunk(mut self, c: ChunkRef) -> Prompt {
        self.segments.push(Segment::Chunk(c));
        self
    }

    pub fn images(&self) -> Vec<ImageId> {
        self.segments
            .iter()
            .filter_map(|s| match s {
                Segment::Image(id) => Some(*id),
                _ => None,
            })
            .collect()
    }

    pub fn chunk_ids(&self) -> Vec<ChunkId> {
        self.segments
            .iter()
            .filter_map(|s| match s {
                Segment::Chunk(c) => Some(c.id),
                _ => None,
            })
            .collect()
    }

    /// Every reusable-segment reference, in prompt order (repeats kept).
    pub fn segment_ids(&self) -> Vec<SegmentId> {
        self.segments
            .iter()
            .filter_map(|s| match s {
                Segment::Image(id) => Some(SegmentId::Image(*id)),
                Segment::Chunk(c) => Some(SegmentId::Chunk(c.id)),
                Segment::Text(_) => None,
            })
            .collect()
    }

    /// Parse the `IMAGE#HANDLE` / `CHUNK#HANDLE` conventions out of a flat
    /// string, mirroring the paper's Fig. 1 dialogues: words starting with
    /// `IMAGE#` become image segments, words starting with `CHUNK#` become
    /// (unresolved) cached-chunk references, everything else stays text.
    pub fn parse(user: UserId, s: &str) -> Prompt {
        let mut p = Prompt::new(user);
        let mut text_run: Vec<&str> = Vec::new();
        for word in s.split_whitespace() {
            let trimmed = word.trim_matches(|c: char| ",.;:!?".contains(c));
            let is_image = trimmed.starts_with("IMAGE#");
            let is_chunk = trimmed.starts_with("CHUNK#");
            if is_image || is_chunk {
                if !text_run.is_empty() {
                    p.segments.push(Segment::Text(text_run.join(" ")));
                    text_run.clear();
                }
                if is_image {
                    p.segments.push(Segment::Image(ImageId::from_handle(trimmed)));
                } else {
                    p.segments
                        .push(Segment::Chunk(ChunkRef::unresolved(ChunkId::from_handle(trimmed))));
                }
            } else {
                text_run.push(word);
            }
        }
        if !text_run.is_empty() {
            p.segments.push(Segment::Text(text_run.join(" ")));
        }
        p
    }
}

/// Synthesise deterministic "pixel" patch features for an image id.
///
/// Stands in for real image bytes (DESIGN.md §2): the vision encoder only
/// needs a deterministic, id-unique input tensor of shape
/// `[img_tokens, patch_dim]`.
pub fn synth_patches(id: ImageId, img_tokens: usize, patch_dim: usize) -> Vec<f32> {
    let mut rng = crate::util::rng::Rng::new(id.0 ^ 0x494D4147); // "IMAG"
    (0..img_tokens * patch_dim).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_interleaved() {
        let p = Prompt::parse(
            UserId(1),
            "My partner and I took these photos IMAGE#EIFFEL2025 IMAGE#LOUVRE2025 please describe them",
        );
        assert_eq!(p.images().len(), 2);
        assert!(matches!(p.segments[0], Segment::Text(_)));
        assert!(matches!(p.segments[1], Segment::Image(_)));
        assert!(matches!(p.segments[3], Segment::Text(_)));
    }

    #[test]
    fn parse_strips_punctuation_from_handles() {
        let p = Prompt::parse(UserId(1), "link IMAGE#A, and IMAGE#B.");
        assert_eq!(p.images(), vec![ImageId::from_handle("IMAGE#A"), ImageId::from_handle("IMAGE#B")]);
    }

    #[test]
    fn parse_chunk_references() {
        let p = Prompt::parse(UserId(1), "given CHUNK#DOC1 and IMAGE#A, answer using CHUNK#DOC2.");
        assert_eq!(
            p.chunk_ids(),
            vec![ChunkId::from_handle("CHUNK#DOC1"), ChunkId::from_handle("CHUNK#DOC2")]
        );
        assert_eq!(p.images(), vec![ImageId::from_handle("IMAGE#A")]);
        assert_eq!(
            p.segment_ids(),
            vec![
                SegmentId::Chunk(ChunkId::from_handle("CHUNK#DOC1")),
                SegmentId::Image(ImageId::from_handle("IMAGE#A")),
                SegmentId::Chunk(ChunkId::from_handle("CHUNK#DOC2")),
            ]
        );
        // Parsed chunk references are unresolved until the engine fills in
        // the canonical token stream.
        for s in &p.segments {
            if let Segment::Chunk(c) = s {
                assert!(!c.is_resolved());
            }
        }
    }

    #[test]
    fn namespace_validation_and_defaults() {
        let ns = Namespace::new("tenant-a").unwrap();
        assert_eq!(ns.as_str(), "tenant-a");
        assert!(!ns.is_default());
        assert!(Namespace::default().is_default());
        assert!(Namespace::new("").is_err());
        assert!(Namespace::new("has space").is_err());
        assert!(Namespace::new("sl/ash").is_err());
        assert!(Namespace::new(&"x".repeat(65)).is_err());
        assert!(Namespace::new(&"x".repeat(64)).is_ok());
        // Prompts default to the root namespace and can be scoped.
        let p = Prompt::parse(UserId(1), "hi IMAGE#A");
        assert!(p.ns.is_default());
        let p = p.in_ns(&ns);
        assert_eq!(p.ns, ns);
        assert_eq!(p.images().len(), 1, "scoping must preserve segments");
    }

    #[test]
    fn image_id_stable() {
        assert_eq!(ImageId::from_handle("IMAGE#X"), ImageId::from_handle("IMAGE#X"));
        assert_ne!(ImageId::from_handle("IMAGE#X"), ImageId::from_handle("IMAGE#Y"));
    }

    #[test]
    fn segment_id_accessors() {
        let img = SegmentId::Image(ImageId(7));
        let chk = SegmentId::Chunk(ChunkId(7));
        assert_ne!(img, chk);
        assert_eq!(img.raw(), chk.raw());
        assert_ne!(img.kind_tag(), chk.kind_tag());
        assert_eq!(img.as_image(), Some(ImageId(7)));
        assert_eq!(img.as_chunk(), None);
        assert_eq!(chk.as_chunk(), Some(ChunkId(7)));
        assert_eq!(chk.kind_str(), "chunk");
    }

    #[test]
    fn synth_patches_deterministic_and_unique() {
        let a = synth_patches(ImageId(5), 8, 4);
        let b = synth_patches(ImageId(5), 8, 4);
        let c = synth_patches(ImageId(6), 8, 4);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 32);
    }
}
