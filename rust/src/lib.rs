//! # MPIC — Position-Independent Multimodal Context Caching
//!
//! Reproduction of *MPIC: Position-Independent Multimodal Context Caching
//! System for Efficient MLLM Serving* (Zhao et al., 2025) as a three-layer
//! Rust + JAX + Pallas system. This crate is **Layer 3**: the serving
//! coordinator. It loads HLO-text artifacts AOT-compiled by
//! `python/compile/aot.py` (Layer 2 model, Layer 1 Pallas selective-attention
//! kernel) and runs them on the PJRT CPU client — Python is never on the
//! request path.
//!
//! Module map (see DESIGN.md §4 for the full system inventory):
//!
//! * [`util`] — substrates built in-tree (JSON, RNG, stats, thread pool,
//!   CLI, logging, bench harness, property-testing helpers).
//! * [`mm`] — multimodal prompt model: segments, tokenizer, linked layout,
//!   sink-bias construction (mirrors `python/compile/model.py`).
//! * [`runtime`] — PJRT runtime: artifact manifest, executable cache,
//!   resident weight buffers, typed execute paths.
//! * [`kv`] — KV-cache subsystem: layout, codec, tiered store
//!   (device/RAM/disk), eviction, paged block accounting, the parallel
//!   transfer engine of paper Fig. 6.
//! * [`cache`] — the Static Library (user uploads) and Dynamic Library
//!   (MRAG references) of paper Fig. 5.
//! * [`retriever`] — MRAG retriever (embedding index, cosine top-k).
//! * [`coordinator`] — the paper's contribution: Linker (Fig. 7),
//!   selection policies (prefix / full-reuse / CacheBlend-r / MPIC-k),
//!   scheduler, serving engine, sessions, metrics.
//! * [`quality`] — fidelity scorer (GPT-score substitute, DESIGN.md §2).
//! * [`workload`] — synthetic MMDU-like / Sparkles-like generators, traces.
//! * [`server`] — JSON-lines TCP serving front end.
//! * [`cluster`] — scale-out serving: cache-aware router, consistent-hash
//!   placement, peer-to-peer KV container transfer (`kv.probe`/`kv.pull`).

pub mod cache;
pub mod cluster;
pub mod coordinator;
pub mod harness;
pub mod kv;
pub mod mm;
pub mod quality;
pub mod retriever;
pub mod runtime;
pub mod server;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Repository-relative default artifact directory.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";
