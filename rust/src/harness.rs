//! Shared experiment harness used by `benches/` and `examples/`.
//!
//! Centralises the boilerplate every figure reproduction needs: engine
//! construction with a scratch store, upfront image upload (the paper's
//! workflow ① precomputation), running a policy over a workload, and
//! scoring against the exact (prefix caching) reference.

use std::sync::Arc;

use crate::coordinator::{Engine, EngineConfig, Policy};
use crate::kv::store::StoreConfig;
use crate::mm::Prompt;
use crate::quality;
use crate::util::stats::Samples;
use crate::workload::Conversation;
use crate::Result;

/// Build an engine on a scratch disk dir for experiment `tag`, with all of
/// the model's artifacts compiled upfront (serving-style startup) so that
/// no measured request pays compilation latency.
pub fn experiment_engine(model: &str, tag: &str) -> Result<Engine> {
    let dir = std::env::temp_dir().join(format!("mpic-bench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let engine = Engine::new(EngineConfig {
        model: model.into(),
        store: StoreConfig { disk_dir: dir, ..Default::default() },
        ..Default::default()
    })?;
    engine.runtime().warmup_model(model, true)?;
    Ok(engine)
}

/// Check that artifacts exist; prints a skip message when not.
pub fn artifacts_ready() -> bool {
    let ok = std::path::Path::new(crate::DEFAULT_ARTIFACT_DIR).join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts` first");
    }
    ok
}

/// Upload (precompute + store) every image of every conversation —
/// the paper's evaluation precomputes the relevant KV caches upfront.
pub fn precompute_images(engine: &Engine, convs: &[Conversation]) -> Result<usize> {
    let mut n = 0;
    for c in convs {
        for img in &c.images {
            let key = crate::kv::KvKey::image(&engine.meta().name, *img);
            if !engine.store().contains(&key) {
                let kv = engine.encode_image(*img)?;
                engine.store().put(kv)?;
                n += 1;
            }
        }
    }
    Ok(n)
}

/// Upload (tokenize + canonical prefill + store) every document of a RAG
/// workload's shared chunk pool, registering each in the engine's chunk
/// library so generated `CHUNK#...` references resolve. Documents whose
/// KV is already stored (the disk tier persists across runs) skip the
/// prefill and only (re)register their token stream; returns the number
/// actually encoded, mirroring [`precompute_images`].
pub fn precompute_chunks(engine: &Engine, pool: &[(String, String)]) -> Result<usize> {
    let mut n = 0;
    for (handle, text) in pool {
        if engine.store().contains(&engine.kv_key(&Default::default(), handle)) {
            let tokens = engine.tokenizer().encode(text);
            engine.chunk_lib.register(handle, text, tokens)?;
        } else {
            engine.upload_chunk(handle, text)?;
            n += 1;
        }
    }
    Ok(n)
}

/// Measurements of one policy over a set of prompts.
#[derive(Debug, Default, Clone)]
pub struct PolicyRun {
    pub policy: String,
    pub ttft_s: Samples,
    pub score: Samples,
    pub kl: Samples,
    pub agreement: Samples,
    pub steps: Samples,
}

/// Run `policy` over prompts, scoring each result against the provided
/// exact references (same order). `refs` may be empty to skip scoring.
pub fn run_policy(
    engine: &Engine,
    prompts: &[Prompt],
    policy: Policy,
    max_new: usize,
    refs: &[crate::coordinator::InferenceResult],
) -> Result<PolicyRun> {
    let mut out = PolicyRun { policy: policy.name(), ..Default::default() };
    for (i, p) in prompts.iter().enumerate() {
        let r = engine.infer(p, policy, max_new)?;
        out.ttft_s.push(r.ttft.total_s);
        out.steps.push(r.ttft.steps as f64);
        if let Some(reference) = refs.get(i) {
            let s = quality::score(reference, &r);
            out.score.push(s.score);
            out.kl.push(s.kl_first);
            out.agreement.push(s.agreement);
        }
    }
    Ok(out)
}

/// Run prefix caching to produce the exact references for scoring.
pub fn exact_references(
    engine: &Engine,
    prompts: &[Prompt],
    max_new: usize,
) -> Result<(Vec<crate::coordinator::InferenceResult>, Samples)> {
    let mut refs = Vec::with_capacity(prompts.len());
    let mut ttft = Samples::new();
    for p in prompts {
        let r = engine.infer(p, Policy::Prefix, max_new)?;
        ttft.push(r.ttft.total_s);
        refs.push(r);
    }
    Ok((refs, ttft))
}

/// Shared store handle type used by ablations.
pub type SharedStore = Arc<crate::kv::KvStore>;
