//! Cluster serving: cache-aware routing + peer-to-peer KV transfer.
//!
//! The store shards across locks (PR 3) and tenants (PR 5) *inside* one
//! process; this module scales out. Position-independent KV makes a
//! segment's cache location-portable by construction (EPIC,
//! arXiv:2410.15332): any worker can splice a pulled segment into any
//! prompt, so workers share their caches instead of recomputing them.
//!
//! ## Topology
//!
//! ```text
//!                        ┌──────────────┐
//!        clients ──────▶ │  mpic router │  consistent-hash (ns, SegmentId)
//!                        │  (stateless) │  + reuse-span affinity scoring
//!                        └──────┬───────┘
//!              ┌────────────────┼────────────────┐
//!              ▼                ▼                ▼
//!        ┌───────────┐    ┌───────────┐    ┌───────────┐
//!        │ worker A  │    │ worker B  │    │ worker C  │   mpic serve
//!        │ engine +  │◀──▶│ engine +  │◀──▶│ engine +  │   --peers ...
//!        │ KvStore   │    │ KvStore   │    │ KvStore   │
//!        └───────────┘    └───────────┘    └───────────┘
//!              ▲   kv.probe / kv.pull (peer KV lane)  ▲
//!              └──────────────────────────────────────┘
//! ```
//!
//! Three coordinated pieces:
//!
//! * **[`crate::kv::Transport`]** — the transfer engine's remote-tier
//!   seam. [`crate::kv::LocalTransport`] keeps today's in-process path;
//!   [`PeerTransport`] (here) speaks the v4 codec container over TCP.
//!   The container already *is* the wire format: a peer pull is
//!   read-from-disk → base64 frame → send, no re-encode on either side.
//! * **Peer KV lane** — internal wire ops `kv.probe {keys}` → residency
//!   bitmap and `kv.pull {key}` → framed container bytes, served by every
//!   worker's control lane. A local miss consults configured peers before
//!   paying the `compute_segment_kv` recompute, with per-peer connect
//!   timeouts, one retry with backoff, and a negative-probe cache so a
//!   flapping peer cannot stall prefill.
//! * **[`router`]** — the `mpic router` front end. Uploads land on the
//!   ring owner of their `(ns, SegmentId)`; generations go to the worker
//!   owning the most of the request's reuse spans (tie-break: live batch
//!   occupancy from a cheap `stats` poll); reply lines proxy verbatim and
//!   a dead worker re-routes to the next candidate.

pub mod router;
pub mod transport;

pub use router::{serve_router, RouteMode, RouterConfig};
pub use transport::{PeerConfig, PeerTransport};

use crate::mm::{Namespace, SegmentId};
use crate::util::rng::fnv1a;

/// Virtual nodes per worker: enough that a 1/2/4-worker ring spreads keys
/// within a few percent of even, cheap enough to rebuild per process.
const VNODES: usize = 64;

/// Consistent-hash ring over `(ns, SegmentId)`. Uploads routed through
/// the ring land deterministically, so a later generation referencing the
/// same segment scores an affinity hit on the same worker — and when the
/// worker set changes, only the keys owned by the touched arcs move
/// (standard consistent-hashing locality).
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Sorted (point, worker) pairs.
    points: Vec<(u64, usize)>,
    n_workers: usize,
}

impl HashRing {
    pub fn new(n_workers: usize) -> HashRing {
        assert!(n_workers > 0, "ring needs at least one worker");
        let mut points = Vec::with_capacity(n_workers * VNODES);
        for w in 0..n_workers {
            for r in 0..VNODES {
                points.push((fnv1a(format!("worker-{w}/vnode-{r}").as_bytes()), w));
            }
        }
        points.sort_unstable();
        points.dedup_by_key(|(p, _)| *p);
        HashRing { points, n_workers }
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// The ring point of one segment key (ns ⊕ kind ⊕ raw id, the same
    /// FNV-1a folding idiom as `KvStore::shard_index`).
    fn key_point(ns: &Namespace, seg: SegmentId) -> u64 {
        let mut h = fnv1a(ns.as_str().as_bytes());
        h = (h ^ seg.kind_tag() as u64).wrapping_mul(0x100_0000_01b3);
        for b in seg.raw().to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
        h
    }

    /// Which worker owns `(ns, seg)`: the first vnode clockwise from the
    /// key's point.
    pub fn owner(&self, ns: &Namespace, seg: SegmentId) -> usize {
        let h = Self::key_point(ns, seg);
        let i = match self.points.binary_search_by(|(p, _)| p.cmp(&h)) {
            Ok(i) => i,
            Err(i) if i == self.points.len() => 0, // wrap past the top
            Err(i) => i,
        };
        self.points[i].1
    }
}

/// Score each worker by how many of the request's reuse spans it owns,
/// given one residency bitmap per worker (`bitmaps[w][i]` ⇔ worker `w`
/// could serve span `i`). Pure function — the router's probe results and
/// the unit tests' synthetic maps feed the same code.
pub fn affinity_scores(n_spans: usize, bitmaps: &[Vec<bool>]) -> Vec<usize> {
    bitmaps
        .iter()
        .map(|bm| bm.iter().take(n_spans).filter(|&&b| b).count())
        .collect()
}

/// Pick the worker with the best span affinity; ties fall back to the
/// least-loaded worker (live batch occupancy from the `stats` poll), and
/// remaining ties to the lowest index (determinism).
pub fn choose_worker(scores: &[usize], occupancy: &[f64]) -> usize {
    assert!(!scores.is_empty());
    let mut best = 0usize;
    for w in 1..scores.len() {
        let load = |i: usize| occupancy.get(i).copied().unwrap_or(0.0);
        if scores[w] > scores[best] || (scores[w] == scores[best] && load(w) < load(best)) {
            best = w;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mm::ImageId;
    use std::collections::HashMap;

    fn ns(s: &str) -> Namespace {
        if s.is_empty() {
            Namespace::default()
        } else {
            Namespace::new(s).unwrap()
        }
    }

    #[test]
    fn ring_is_deterministic_and_spreads_keys() {
        let ring = HashRing::new(4);
        let ring2 = HashRing::new(4);
        let mut counts: HashMap<usize, usize> = HashMap::new();
        for i in 0..4000u64 {
            let seg = SegmentId::Image(ImageId(i));
            let w = ring.owner(&ns("tenant"), seg);
            assert_eq!(w, ring2.owner(&ns("tenant"), seg), "owner must be deterministic");
            *counts.entry(w).or_default() += 1;
        }
        assert_eq!(counts.len(), 4, "all workers should own keys: {counts:?}");
        for (&w, &c) in &counts {
            assert!((500..=1800).contains(&c), "worker {w} owns {c} of 4000 — too skewed");
        }
    }

    #[test]
    fn ring_namespaces_and_kinds_hash_apart() {
        let ring = HashRing::new(8);
        let mut differs = 0;
        for i in 0..64u64 {
            let img = SegmentId::Image(ImageId(i));
            let chk = SegmentId::Chunk(crate::mm::ChunkId(i));
            if ring.owner(&ns("a"), img) != ring.owner(&ns("b"), img) {
                differs += 1;
            }
            if ring.owner(&ns("a"), img) != ring.owner(&ns("a"), chk) {
                differs += 1;
            }
        }
        assert!(differs > 32, "ns/kind must perturb placement (differs={differs})");
    }

    #[test]
    fn ring_growth_moves_only_a_fraction_of_keys() {
        // The consistent-hashing property: going 4 → 5 workers remaps
        // roughly 1/5 of the keys, not all of them.
        let before = HashRing::new(4);
        let after = HashRing::new(5);
        let n = 4000u64;
        let moved = (0..n)
            .filter(|&i| {
                let seg = SegmentId::Image(ImageId(i));
                before.owner(&ns("t"), seg) != after.owner(&ns("t"), seg)
            })
            .count();
        assert!(
            moved < (n as usize) / 2,
            "adding one worker moved {moved}/{n} keys — not consistent hashing"
        );
        assert!(moved > 0, "a bigger ring must claim some keys");
    }

    // ------------------------------------------------------------------
    // Satellite: affinity scoring against synthetic residency maps.
    // ------------------------------------------------------------------

    #[test]
    fn affinity_picks_worker_owning_most_spans() {
        // Worker 1 owns 3 of the 4 reuse spans, worker 0 owns 1, worker 2
        // none. Occupancy would prefer worker 2 — affinity must win.
        let bitmaps = vec![
            vec![true, false, false, false],
            vec![true, true, true, false],
            vec![false, false, false, false],
        ];
        let scores = affinity_scores(4, &bitmaps);
        assert_eq!(scores, vec![1, 3, 0]);
        assert_eq!(choose_worker(&scores, &[0.0, 9.0, 0.0]), 1);
    }

    #[test]
    fn affinity_tie_falls_back_to_least_loaded() {
        // Workers 0 and 2 both own 2 spans; worker 2 is idle, worker 0 is
        // deep in a batch — the tie-break must pick 2.
        let bitmaps = vec![
            vec![true, true, false],
            vec![false, false, true],
            vec![true, false, true],
        ];
        let scores = affinity_scores(3, &bitmaps);
        assert_eq!(scores, vec![2, 1, 2]);
        assert_eq!(choose_worker(&scores, &[7.0, 1.0, 2.0]), 2);
        // Full tie (no spans anywhere): least-loaded wins outright.
        let cold = affinity_scores(3, &[vec![false; 3], vec![false; 3], vec![false; 3]]);
        assert_eq!(choose_worker(&cold, &[3.0, 0.5, 2.0]), 1);
        // Everything equal: lowest index, deterministically.
        assert_eq!(choose_worker(&[0, 0, 0], &[1.0, 1.0, 1.0]), 0);
    }

    #[test]
    fn affinity_scores_ignore_bits_past_the_span_count() {
        // A worker reporting a longer bitmap than the request has spans
        // (stale probe reply) must not score phantom spans.
        let bitmaps = vec![vec![true, true, true, true], vec![true, true, false, false]];
        assert_eq!(affinity_scores(2, &bitmaps), vec![2, 2]);
    }
}
