//! The `mpic router` front end: a stateless cache-aware proxy in front of
//! N workers (see the topology diagram in [`crate::cluster`]).
//!
//! Placement policy, per request:
//!
//! * **uploads** (`upload`, `add_reference`, `chunk.upload`) go to the
//!   consistent-hash owner of their `(ns, SegmentId)` — deterministic, so
//!   later generations referencing the segment find it where the ring
//!   says it is;
//! * **generations** (`infer`, `chat`) with reuse spans are scored by
//!   residency: each worker answers one `kv.probe` over the prompt's
//!   spans, [`super::affinity_scores`] counts what each owns, and ties
//!   break toward the lowest live occupancy (`stats.metrics.pipeline.
//!   inflight_now`, polled in the background). The winner's request is
//!   stamped `"routed":"affinity"` so the worker's
//!   `cluster.routed_affinity_hits` counter records the placement;
//! * **everything else** (and all traffic in `RouteMode::RoundRobin`)
//!   rotates round-robin.
//!
//! Reply lines are proxied verbatim — stream chunks included — and a
//! worker that cannot be reached re-routes the request to the next
//! candidate instead of failing the client.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::mm::{ChunkId, ImageId, Namespace, Prompt, SegmentId, UserId};
use crate::server::{Client, PeerUnreachable};
use crate::util::json::Value;
use crate::Result;

use super::{affinity_scores, choose_worker, HashRing};

/// How generations are placed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteMode {
    /// Reuse-span residency scoring with occupancy tie-breaks.
    Affinity,
    /// Ignore residency; rotate. The bench's control arm.
    RoundRobin,
}

impl RouteMode {
    pub fn parse(s: &str) -> Result<RouteMode> {
        match s {
            "affinity" => Ok(RouteMode::Affinity),
            "rr" | "round-robin" => Ok(RouteMode::RoundRobin),
            other => anyhow::bail!("unknown route mode {other:?} (want affinity|rr)"),
        }
    }
}

/// Router tunables.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Worker serving addresses, in ring order.
    pub workers: Vec<SocketAddr>,
    pub mode: RouteMode,
    /// Deadline on probe connects/reads (generation forwards stream
    /// without a read deadline).
    pub probe_timeout: Duration,
    /// Occupancy poll period.
    pub stats_interval: Duration,
}

impl RouterConfig {
    pub fn new(workers: Vec<SocketAddr>) -> RouterConfig {
        RouterConfig {
            workers,
            mode: RouteMode::Affinity,
            probe_timeout: Duration::from_millis(300),
            stats_interval: Duration::from_millis(500),
        }
    }
}

struct Shared {
    cfg: RouterConfig,
    ring: HashRing,
    rr: AtomicUsize,
    /// Live `inflight_now` per worker, refreshed by the poller thread.
    occupancy: Mutex<Vec<f64>>,
    shutdown: AtomicBool,
}

/// Run the router until an accepted `{"op":"shutdown"}`. Binds `addr`,
/// reports the bound address through `on_ready`, then blocks accepting.
pub fn serve_router(
    cfg: RouterConfig,
    addr: &str,
    on_ready: impl FnOnce(SocketAddr),
) -> Result<()> {
    anyhow::ensure!(!cfg.workers.is_empty(), "router needs at least one worker");
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    on_ready(local);
    log::info!(
        "router: listening on {local}, {} workers, mode {:?}",
        cfg.workers.len(),
        cfg.mode
    );

    let shared = Arc::new(Shared {
        ring: HashRing::new(cfg.workers.len()),
        rr: AtomicUsize::new(0),
        occupancy: Mutex::new(vec![0.0; cfg.workers.len()]),
        shutdown: AtomicBool::new(false),
        cfg,
    });

    // Occupancy poller: one cheap `stats` per worker per interval.
    let poller = {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || poll_occupancy(&shared))
    };

    let mut handlers = Vec::new();
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let shared = Arc::clone(&shared);
                handlers.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(s, &shared, local) {
                        log::debug!("router: connection ended: {e}");
                    }
                }));
            }
            Err(e) => log::warn!("router: accept error: {e}"),
        }
    }
    shared.shutdown.store(true, Ordering::SeqCst);
    for h in handlers {
        let _ = h.join();
    }
    let _ = poller.join();
    log::info!("router: shut down");
    Ok(())
}

fn poll_occupancy(shared: &Shared) {
    // Sleep in small slices so shutdown is honoured promptly.
    let slice = Duration::from_millis(50);
    loop {
        let mut waited = Duration::ZERO;
        while waited < shared.cfg.stats_interval {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(slice);
            waited += slice;
        }
        for (w, &addr) in shared.cfg.workers.iter().enumerate() {
            let inflight = worker_inflight(addr, shared.cfg.probe_timeout).unwrap_or(0.0);
            shared.occupancy.lock().unwrap()[w] = inflight;
        }
    }
}

fn worker_inflight(addr: SocketAddr, timeout: Duration) -> Result<f64> {
    let mut c = Client::connect_timeout(addr, timeout)?;
    let resp = c.call(&Value::obj(vec![("op", Value::str("stats")), ("id", Value::str("occ"))]))?;
    resp.get("metrics")?.get("pipeline")?.get("inflight_now")?.as_f64()
}

fn write_line(writer: &mut TcpStream, v: &Value) -> Result<()> {
    writer.write_all(v.encode().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn error_line(id: Option<&Value>, msg: &str) -> Value {
    let mut v = Value::obj(vec![
        ("ok", Value::Bool(false)),
        ("code", Value::str("internal")),
        ("error", Value::str(msg)),
    ]);
    if let Some(id) = id {
        v.set("id", id.clone());
    }
    v
}

fn handle_conn(stream: TcpStream, shared: &Shared, local: SocketAddr) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    // Per-connection upstream cache: one Client per worker, recreated on
    // failure. Requests on one downstream connection stay serial, so the
    // cached upstreams never interleave replies.
    let mut upstreams: HashMap<usize, Client> = HashMap::new();
    for line in reader.lines() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let req = match Value::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                write_line(&mut writer, &error_line(None, &format!("bad JSON: {e}")))?;
                continue;
            }
        };
        let id = req.opt("id").cloned();
        let op = req.opt("op").and_then(|o| o.as_str().ok()).unwrap_or("").to_string();
        if op == "shutdown" {
            // Shut the *router* down; workers have their own lifecycles.
            let mut bye = Value::obj(vec![("ok", Value::Bool(true)), ("bye", Value::Bool(true))]);
            if let Some(id) = &id {
                bye.set("id", id.clone());
            }
            write_line(&mut writer, &bye)?;
            shared.shutdown.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(local); // unblock the acceptor
            break;
        }
        let (candidates, req) = route(shared, &op, req, &mut upstreams);
        let mut answered = false;
        let mut wrote = false;
        for w in candidates {
            match forward(shared, w, &mut upstreams, &req, &mut writer, &mut wrote) {
                Ok(()) => {
                    answered = true;
                    break;
                }
                Err(e) => {
                    // The worker is unreachable: drop its cached client
                    // and re-route to the next candidate — but only if no
                    // reply line reached the client yet (re-sending after
                    // a partial stream would duplicate output).
                    log::debug!("router: worker {w} failed, re-routing: {e}");
                    upstreams.remove(&w);
                    if wrote {
                        break;
                    }
                }
            }
        }
        if !answered {
            write_line(&mut writer, &error_line(id.as_ref(), "no reachable worker"))?;
        }
    }
    Ok(())
}

/// Decide the candidate order for one request (preferred first) and stamp
/// affinity-placed generations.
fn route(
    shared: &Shared,
    op: &str,
    mut req: Value,
    upstreams: &mut HashMap<usize, Client>,
) -> (Vec<usize>, Value) {
    let n = shared.cfg.workers.len();
    let rr_from = |start: usize| (0..n).map(|i| (start + i) % n).collect::<Vec<_>>();
    let ns = req
        .opt("ns")
        .and_then(|s| s.as_str().ok())
        .and_then(|s| Namespace::new(s).ok())
        .unwrap_or_default();
    if shared.cfg.mode == RouteMode::Affinity {
        // Uploads: the ring owner, deterministically.
        let seg = match op {
            "upload" | "add_reference" => req
                .opt("handle")
                .and_then(|h| h.as_str().ok())
                .map(|h| SegmentId::Image(ImageId::from_handle(h))),
            "chunk.upload" => req
                .opt("handle")
                .and_then(|h| h.as_str().ok())
                .map(|h| SegmentId::Chunk(ChunkId::from_handle(h))),
            _ => None,
        };
        if let Some(seg) = seg {
            return (rr_from(shared.ring.owner(&ns, seg)), req);
        }
        // Generations: probe residency of the prompt's reuse spans.
        if op == "infer" || op == "chat" {
            let spans = req
                .opt("text")
                .and_then(|t| t.as_str().ok())
                .map(|t| Prompt::parse(UserId(0), t).segment_ids())
                .unwrap_or_default();
            if !spans.is_empty() {
                let bitmaps = probe_workers(shared, &ns, &spans, upstreams);
                let scores = affinity_scores(spans.len(), &bitmaps);
                let occupancy = shared.occupancy.lock().unwrap().clone();
                let winner = choose_worker(&scores, &occupancy);
                if scores[winner] > 0 {
                    req.set("routed", Value::str("affinity"));
                }
                // Failover order: by descending score, winner first.
                let mut order = rr_from(winner);
                order.sort_by_key(|&w| (w != winner, std::cmp::Reverse(scores[w])));
                return (order, req);
            }
        }
    }
    (rr_from(shared.rr.fetch_add(1, Ordering::Relaxed) % n), req)
}

/// One `kv.probe` per worker over the request's spans. A worker that
/// cannot be probed scores an all-false bitmap (it can still serve the
/// request as a failover candidate).
fn probe_workers(
    shared: &Shared,
    ns: &Namespace,
    spans: &[SegmentId],
    upstreams: &mut HashMap<usize, Client>,
) -> Vec<Vec<bool>> {
    let keys = Value::arr(
        spans
            .iter()
            .map(|&seg| {
                let kind = match seg {
                    SegmentId::Image(_) => "image",
                    SegmentId::Chunk(_) => "chunk",
                };
                let mut k = Value::obj(vec![
                    ("kind", Value::str(kind)),
                    ("segment", Value::str(format!("{:016x}", seg.raw()))),
                ]);
                if !ns.is_default() {
                    k.set("ns", Value::str(ns.as_str()));
                }
                k
            })
            .collect(),
    );
    let req = Value::obj(vec![
        ("v", Value::num(3.0)),
        ("op", Value::str("kv.probe")),
        ("id", Value::str("route")),
        ("keys", keys),
    ]);
    (0..shared.cfg.workers.len())
        .map(|w| match probe_one(shared, w, &req, upstreams) {
            Ok(bm) => bm,
            Err(e) => {
                log::debug!("router: probe of worker {w} failed: {e}");
                upstreams.remove(&w);
                vec![false; spans.len()]
            }
        })
        .collect()
}

/// One probe round-trip against one worker, under the probe deadline.
fn probe_one(
    shared: &Shared,
    w: usize,
    req: &Value,
    upstreams: &mut HashMap<usize, Client>,
) -> Result<Vec<bool>> {
    let c = upstream(shared, w, upstreams)?;
    c.set_read_deadline(Some(shared.cfg.probe_timeout))?;
    let resp = c.call(req);
    c.set_read_deadline(None)?;
    let resp = resp?;
    anyhow::ensure!(resp.get("ok")?.as_bool()?, "probe rejected");
    Ok(resp.get("bitmap")?.as_arr()?.iter().map(|b| b.as_bool().unwrap_or(false)).collect())
}

/// The cached upstream client for worker `w`, connecting if needed.
fn upstream<'a>(
    shared: &Shared,
    w: usize,
    upstreams: &'a mut HashMap<usize, Client>,
) -> Result<&'a mut Client> {
    match upstreams.entry(w) {
        std::collections::hash_map::Entry::Occupied(e) => Ok(e.into_mut()),
        std::collections::hash_map::Entry::Vacant(e) => {
            let mut c = Client::connect_timeout(shared.cfg.workers[w], shared.cfg.probe_timeout)?;
            // Forwarded generations stream with unbounded decode gaps.
            c.set_read_deadline(None)?;
            Ok(e.insert(c))
        }
    }
}

/// Forward one request to worker `w` and proxy every reply line verbatim
/// until the terminal (non-chunk) line.
fn forward(
    shared: &Shared,
    w: usize,
    upstreams: &mut HashMap<usize, Client>,
    req: &Value,
    writer: &mut TcpStream,
    wrote: &mut bool,
) -> Result<()> {
    let c = upstream(shared, w, upstreams)?;
    c.send(req)?;
    loop {
        let line = c.recv().map_err(|e| {
            if e.downcast_ref::<PeerUnreachable>().is_some() {
                e
            } else {
                e.context(format!("worker {w} reply stream"))
            }
        })?;
        write_line(writer, &line)?;
        *wrote = true;
        let is_chunk = line.opt("stream").and_then(|s| s.as_bool().ok()).unwrap_or(false);
        if !is_chunk {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted worker: answers `kv.probe` with a fixed bitmap and every
    /// other op with `{ok, id, worker: idx}` (+ an optional leading chunk
    /// line), so tests can see *which* worker served and that chunk lines
    /// proxy through.
    fn fake_worker(idx: usize, resident: Vec<bool>, chunk_first: bool) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let resident = resident.clone();
                std::thread::spawn(move || {
                    let mut w = stream.try_clone().unwrap();
                    let r = BufReader::new(stream);
                    for line in r.lines() {
                        let Ok(line) = line else { break };
                        let req = Value::parse(&line).unwrap();
                        let id = req.opt("id").cloned().unwrap_or(Value::Null);
                        let op = req.opt("op").and_then(|o| o.as_str().ok()).unwrap_or("");
                        let mut out = String::new();
                        if op == "kv.probe" {
                            let n = req.get("keys").unwrap().as_arr().unwrap().len();
                            let bits: Vec<Value> = (0..n)
                                .map(|i| Value::Bool(resident.get(i).copied().unwrap_or(false)))
                                .collect();
                            let resp = Value::obj(vec![
                                ("ok", Value::Bool(true)),
                                ("id", id),
                                ("bitmap", Value::arr(bits)),
                            ]);
                            out.push_str(&resp.encode());
                            out.push('\n');
                        } else {
                            if chunk_first && op == "infer" {
                                let chunk = Value::obj(vec![
                                    ("ok", Value::Bool(true)),
                                    ("id", id.clone()),
                                    ("stream", Value::Bool(true)),
                                    ("seq", Value::num(0.0)),
                                ]);
                                out.push_str(&chunk.encode());
                                out.push('\n');
                            }
                            let mut resp = Value::obj(vec![
                                ("ok", Value::Bool(true)),
                                ("id", id),
                                ("worker", Value::num(idx as f64)),
                            ]);
                            if let Some(routed) = req.opt("routed") {
                                resp.set("routed_seen", routed.clone());
                            }
                            out.push_str(&resp.encode());
                            out.push('\n');
                        }
                        if w.write_all(out.as_bytes()).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    fn start_router(cfg: RouterConfig) -> SocketAddr {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            serve_router(cfg, "127.0.0.1:0", |a| tx.send(a).unwrap()).unwrap();
        });
        rx.recv().unwrap()
    }

    fn fast_cfg(workers: Vec<SocketAddr>) -> RouterConfig {
        RouterConfig {
            workers,
            mode: RouteMode::Affinity,
            probe_timeout: Duration::from_millis(300),
            stats_interval: Duration::from_millis(60_000), // poller idle in tests
        }
    }

    #[test]
    fn generation_routes_to_span_owner_and_is_stamped() {
        // Worker 1 owns the span; the reply must come from worker 1 and
        // the forwarded request must carry the affinity stamp.
        let w0 = fake_worker(0, vec![false], false);
        let w1 = fake_worker(1, vec![true], true);
        let router = start_router(fast_cfg(vec![w0, w1]));
        let mut c = Client::connect(router).unwrap();
        let req = Value::parse(
            r#"{"v":3,"id":"g","op":"infer","user":1,"text":"describe IMAGE#A","stream":true}"#,
        )
        .unwrap();
        let mut chunks = 0;
        let done = c.call_stream(&req, |_| chunks += 1).unwrap();
        assert_eq!(done.get("worker").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(done.get("routed_seen").unwrap().as_str().unwrap(), "affinity");
        assert_eq!(chunks, 1, "chunk lines must proxy through verbatim");
        // A spanless op round-robins and is never stamped.
        let stats = c
            .call(&Value::parse(r#"{"op":"infer","id":"s","user":1,"text":"hello"}"#).unwrap())
            .unwrap();
        assert!(stats.opt("routed_seen").is_none());
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }

    #[test]
    fn uploads_land_on_the_ring_owner_deterministically() {
        let w0 = fake_worker(0, vec![], false);
        let w1 = fake_worker(1, vec![], false);
        let router = start_router(fast_cfg(vec![w0, w1]));
        let ring = HashRing::new(2);
        let mut c = Client::connect(router).unwrap();
        for handle in ["IMAGE#A", "IMAGE#B", "IMAGE#C", "IMAGE#D"] {
            let seg = SegmentId::Image(ImageId::from_handle(handle));
            let want = ring.owner(&Namespace::default(), seg);
            let req = Value::obj(vec![
                ("op", Value::str("upload")),
                ("id", Value::str(handle)),
                ("user", Value::num(1.0)),
                ("handle", Value::str(handle)),
            ]);
            let resp = c.call(&req).unwrap();
            assert_eq!(
                resp.get("worker").unwrap().as_f64().unwrap(),
                want as f64,
                "upload {handle} must land on its ring owner"
            );
        }
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }

    #[test]
    fn dead_worker_re_routes_to_next_candidate() {
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let live = fake_worker(1, vec![false], false);
        // Round-robin mode so every request starts from the rr cursor —
        // some of them will prefer the dead worker first.
        let mut cfg = fast_cfg(vec![dead, live]);
        cfg.mode = RouteMode::RoundRobin;
        let router = start_router(cfg);
        let mut c = Client::connect(router).unwrap();
        for i in 0..4 {
            let req = Value::obj(vec![
                ("op", Value::str("ping")),
                ("id", Value::str(format!("p{i}"))),
            ]);
            let resp = c.call(&req).unwrap();
            assert!(resp.get("ok").unwrap().as_bool().unwrap(), "re-route must succeed: {resp:?}");
            assert_eq!(resp.get("worker").unwrap().as_f64().unwrap(), 1.0);
        }
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }
}
