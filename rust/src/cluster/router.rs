//! The `mpic router` front end: a stateless cache-aware proxy in front of
//! N workers (see the topology diagram in [`crate::cluster`]).
//!
//! Placement policy, per request:
//!
//! * **uploads** (`upload`, `add_reference`, `chunk.upload`) go to the
//!   consistent-hash owner of their `(ns, SegmentId)` — deterministic, so
//!   later generations referencing the segment find it where the ring
//!   says it is;
//! * **generations** (`infer`, `chat`) with reuse spans are scored by
//!   residency: each worker answers one `kv.probe` over the prompt's
//!   spans, [`super::affinity_scores`] counts what each owns, and ties
//!   break toward the lowest live occupancy (`stats.metrics.pipeline.
//!   inflight_now`, polled in the background). The winner's request is
//!   stamped `"routed":"affinity"` so the worker's
//!   `cluster.routed_affinity_hits` counter records the placement;
//! * **everything else** (and all traffic in `RouteMode::RoundRobin`)
//!   rotates round-robin.
//!
//! Reply lines are proxied verbatim — stream chunks included — and a
//! worker that cannot be reached re-routes the request to the next
//! candidate instead of failing the client.
//!
//! Observability: generations forwarded without a client-supplied
//! `"trace"` envelope field get a fresh id minted here, so every routed
//! request is traceable end-to-end (the worker echoes the id on its final
//! reply and `debug.trace get` addresses the recorded spans). The router
//! also answers `stats.cluster` — per-worker `stats.metrics` snapshots
//! plus a cross-worker aggregate — and, with a `metrics_addr`, serves that
//! aggregate as Prometheus text exposition over HTTP.

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::mm::{ChunkId, ImageId, Namespace, Prompt, SegmentId, UserId};
use crate::server::{Client, PeerUnreachable};
use crate::util::json::Value;
use crate::util::sync::{LockRank, OrderedMutex};
use crate::util::trace::TraceId;
use crate::Result;

use super::{affinity_scores, choose_worker, HashRing};

/// How generations are placed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteMode {
    /// Reuse-span residency scoring with occupancy tie-breaks.
    Affinity,
    /// Ignore residency; rotate. The bench's control arm.
    RoundRobin,
}

impl RouteMode {
    pub fn parse(s: &str) -> Result<RouteMode> {
        match s {
            "affinity" => Ok(RouteMode::Affinity),
            "rr" | "round-robin" => Ok(RouteMode::RoundRobin),
            other => anyhow::bail!("unknown route mode {other:?} (want affinity|rr)"),
        }
    }
}

/// Router tunables.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Worker serving addresses, in ring order.
    pub workers: Vec<SocketAddr>,
    pub mode: RouteMode,
    /// Deadline on probe connects/reads (generation forwards stream
    /// without a read deadline).
    pub probe_timeout: Duration,
    /// Occupancy poll period.
    pub stats_interval: Duration,
    /// `HOST:PORT` for a cluster-level Prometheus scrape endpoint. Each
    /// scrape pulls a fresh `stats` snapshot from every worker and renders
    /// the aggregate; `None` disables the endpoint.
    pub metrics_addr: Option<String>,
}

impl RouterConfig {
    pub fn new(workers: Vec<SocketAddr>) -> RouterConfig {
        RouterConfig {
            workers,
            mode: RouteMode::Affinity,
            probe_timeout: Duration::from_millis(300),
            stats_interval: Duration::from_millis(500),
            metrics_addr: None,
        }
    }
}

struct Shared {
    cfg: RouterConfig,
    ring: HashRing,
    rr: AtomicUsize,
    /// Live `inflight_now` per worker, refreshed by the poller thread.
    occupancy: OrderedMutex<Vec<f64>>,
    shutdown: AtomicBool,
}

/// Run the router until an accepted `{"op":"shutdown"}`. Binds `addr`,
/// reports the bound address through `on_ready`, then blocks accepting.
pub fn serve_router(
    cfg: RouterConfig,
    addr: &str,
    on_ready: impl FnOnce(SocketAddr),
) -> Result<()> {
    anyhow::ensure!(!cfg.workers.is_empty(), "router needs at least one worker");
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    on_ready(local);
    log::info!(
        "router: listening on {local}, {} workers, mode {:?}",
        cfg.workers.len(),
        cfg.mode
    );

    let shared = Arc::new(Shared {
        ring: HashRing::new(cfg.workers.len()),
        rr: AtomicUsize::new(0),
        occupancy: OrderedMutex::new(LockRank::Router, vec![0.0; cfg.workers.len()]),
        shutdown: AtomicBool::new(false),
        cfg,
    });

    // Occupancy poller: one cheap `stats` per worker per interval.
    let poller = {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || poll_occupancy(&shared))
    };

    // Cluster-level Prometheus endpoint: the same HTTP loop the workers
    // use, rendering the cross-worker aggregate instead of one snapshot.
    let metrics_stop = Arc::new(AtomicBool::new(false));
    let mut metrics_thread = None;
    if let Some(maddr) = shared.cfg.metrics_addr.clone() {
        let sh = Arc::clone(&shared);
        let (bound, handle) =
            crate::server::serve_metrics_http(&maddr, Arc::clone(&metrics_stop), move || {
                let snaps: Vec<Value> = sh
                    .cfg
                    .workers
                    .iter()
                    .filter_map(|&w| worker_snapshot(w, sh.cfg.probe_timeout).ok())
                    .collect();
                crate::coordinator::metrics::prometheus_from_snapshot(&aggregate_snapshots(&snaps))
            })?;
        log::info!("router: metrics endpoint listening on {bound}");
        metrics_thread = Some(handle);
    }

    let mut handlers = Vec::new();
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let shared = Arc::clone(&shared);
                handlers.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(s, &shared, local) {
                        log::debug!("router: connection ended: {e}");
                    }
                }));
            }
            Err(e) => log::warn!("router: accept error: {e}"),
        }
    }
    shared.shutdown.store(true, Ordering::SeqCst);
    for h in handlers {
        let _ = h.join();
    }
    let _ = poller.join();
    metrics_stop.store(true, Ordering::SeqCst);
    if let Some(h) = metrics_thread {
        let _ = h.join();
    }
    log::info!("router: shut down");
    Ok(())
}

fn poll_occupancy(shared: &Shared) {
    // Sleep in small slices so shutdown is honoured promptly.
    let slice = Duration::from_millis(50);
    loop {
        let mut waited = Duration::ZERO;
        while waited < shared.cfg.stats_interval {
            if shared.shutdown.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(slice);
            waited += slice;
        }
        for (w, &addr) in shared.cfg.workers.iter().enumerate() {
            let inflight = worker_inflight(addr, shared.cfg.probe_timeout).unwrap_or(0.0);
            shared.occupancy.lock()[w] = inflight;
        }
    }
}

fn worker_inflight(addr: SocketAddr, timeout: Duration) -> Result<f64> {
    let mut c = Client::connect_timeout(addr, timeout)?;
    let resp = c.call(&Value::obj(vec![("op", Value::str("stats")), ("id", Value::str("occ"))]))?;
    resp.get("metrics")?.get("pipeline")?.get("inflight_now")?.as_f64()
}

/// One worker's full `stats.metrics` snapshot, under the probe deadline.
fn worker_snapshot(addr: SocketAddr, timeout: Duration) -> Result<Value> {
    let mut c = Client::connect_timeout(addr, timeout)?;
    let resp = c.call(&Value::obj(vec![("op", Value::str("stats")), ("id", Value::str("agg"))]))?;
    Ok(resp.get("metrics")?.clone())
}

/// The `stats.cluster` reply body: per-worker snapshots (with per-worker
/// reachability) plus the cross-worker aggregate the router's own metrics
/// endpoint also serves.
fn cluster_stats(shared: &Shared) -> Value {
    let mut workers = Vec::new();
    let mut snaps = Vec::new();
    for &addr in &shared.cfg.workers {
        let mut w = Value::obj(vec![("addr", Value::str(addr.to_string()))]);
        match worker_snapshot(addr, shared.cfg.probe_timeout) {
            Ok(snap) => {
                w.set("ok", Value::Bool(true));
                w.set("metrics", snap.clone());
                snaps.push(snap);
            }
            Err(e) => {
                w.set("ok", Value::Bool(false));
                w.set("error", Value::str(format!("{e:#}")));
            }
        }
        workers.push(w);
    }
    Value::obj(vec![
        ("workers", Value::arr(workers)),
        ("metrics", aggregate_snapshots(&snaps)),
    ])
}

/// Sum worker snapshots into one cluster-level `stats.metrics` tree.
///
/// Counters and rates add across workers; `uptime_s` takes the oldest
/// worker; the fixed-bucket histogram families merge bucket-wise (every
/// worker uses identical bounds). Per-op latency summaries are omitted —
/// quantiles do not compose across hosts — which the Prometheus renderer
/// tolerates by skipping absent fields.
fn aggregate_snapshots(snaps: &[Value]) -> Value {
    let sum_key =
        |key: &str| -> f64 { snaps.iter().filter_map(|s| s.opt(key)?.as_f64().ok()).sum() };
    let mut out = Value::obj(vec![("workers", Value::num(snaps.len() as f64))]);
    for key in
        ["requests", "tokens_out", "throughput_rps", "throughput_tps", "window_rps", "window_tps"]
    {
        out.set(key, Value::num(sum_key(key)));
    }
    let uptime =
        snaps.iter().filter_map(|s| s.opt("uptime_s")?.as_f64().ok()).fold(0.0, f64::max);
    out.set("uptime_s", Value::num(uptime));
    // Flat subtrees: every numeric leaf sums across workers (non-numeric
    // members — the pipeline's summary blocks — are dropped).
    for key in ["kv", "cluster", "pipeline"] {
        let mut acc: BTreeMap<String, f64> = BTreeMap::new();
        for s in snaps {
            let Some(obj) = s.opt(key).and_then(|v| v.as_obj().ok()) else { continue };
            for (k, v) in obj {
                if let Ok(x) = v.as_f64() {
                    *acc.entry(k.clone()).or_insert(0.0) += x;
                }
            }
        }
        if !acc.is_empty() {
            out.set(key, Value::Obj(acc.into_iter().map(|(k, v)| (k, Value::num(v))).collect()));
        }
    }
    // Histogram families: element-wise bucket sums, summed sum/count.
    let mut hists: BTreeMap<String, (Value, Vec<f64>, f64, f64)> = BTreeMap::new();
    for s in snaps {
        let Some(obj) = s.opt("histograms").and_then(|v| v.as_obj().ok()) else { continue };
        for (name, h) in obj {
            let Some(counts) = h.opt("counts").and_then(|v| v.as_arr().ok()) else { continue };
            let le = h.opt("le").cloned().unwrap_or(Value::Arr(Vec::new()));
            let sum = h.opt("sum").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            let count = h.opt("count").and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
            let e = hists.entry(name.clone()).or_insert_with(|| (le, vec![0.0; counts.len()], 0.0, 0.0));
            if e.1.len() < counts.len() {
                e.1.resize(counts.len(), 0.0);
            }
            for (i, c) in counts.iter().enumerate() {
                e.1[i] += c.as_f64().unwrap_or(0.0);
            }
            e.2 += sum;
            e.3 += count;
        }
    }
    if !hists.is_empty() {
        let merged = hists
            .into_iter()
            .map(|(name, (le, counts, sum, count))| {
                let h = Value::obj(vec![
                    ("le", le),
                    ("counts", Value::arr(counts.into_iter().map(Value::num).collect())),
                    ("sum", Value::num(sum)),
                    ("count", Value::num(count)),
                ]);
                (name, h)
            })
            .collect();
        out.set("histograms", Value::Obj(merged));
    }
    out
}

fn write_line(writer: &mut TcpStream, v: &Value) -> Result<()> {
    writer.write_all(v.encode().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    Ok(())
}

fn error_line(id: Option<&Value>, msg: &str) -> Value {
    let mut v = Value::obj(vec![
        ("ok", Value::Bool(false)),
        ("code", Value::str("internal")),
        ("error", Value::str(msg)),
    ]);
    if let Some(id) = id {
        v.set("id", id.clone());
    }
    v
}

fn handle_conn(stream: TcpStream, shared: &Shared, local: SocketAddr) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    // Per-connection upstream cache: one Client per worker, recreated on
    // failure. Requests on one downstream connection stay serial, so the
    // cached upstreams never interleave replies.
    let mut upstreams: HashMap<usize, Client> = HashMap::new();
    for line in reader.lines() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut req = match Value::parse(&line) {
            Ok(v) => v,
            Err(e) => {
                write_line(&mut writer, &error_line(None, &format!("bad JSON: {e}")))?;
                continue;
            }
        };
        let id = req.opt("id").cloned();
        let op = req.opt("op").and_then(|o| o.as_str().ok()).unwrap_or("").to_string();
        // Generations forwarded without a client trace id get one minted
        // here, so the worker-side spans of a routed request are always
        // addressable from the `trace` echoed on the final reply.
        if (op == "infer" || op == "chat") && req.opt("trace").is_none() {
            req.set("trace", Value::str(TraceId::fresh().hex()));
        }
        if op == "stats.cluster" {
            let mut resp = cluster_stats(shared);
            resp.set("ok", Value::Bool(true));
            if let Some(id) = &id {
                resp.set("id", id.clone());
            }
            write_line(&mut writer, &resp)?;
            continue;
        }
        if op == "shutdown" {
            // Shut the *router* down; workers have their own lifecycles.
            let mut bye = Value::obj(vec![("ok", Value::Bool(true)), ("bye", Value::Bool(true))]);
            if let Some(id) = &id {
                bye.set("id", id.clone());
            }
            write_line(&mut writer, &bye)?;
            shared.shutdown.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect(local); // unblock the acceptor
            break;
        }
        let (candidates, req) = route(shared, &op, req, &mut upstreams);
        let mut answered = false;
        let mut wrote = false;
        for w in candidates {
            match forward(shared, w, &mut upstreams, &req, &mut writer, &mut wrote) {
                Ok(()) => {
                    answered = true;
                    break;
                }
                Err(e) => {
                    // The worker is unreachable: drop its cached client
                    // and re-route to the next candidate — but only if no
                    // reply line reached the client yet (re-sending after
                    // a partial stream would duplicate output).
                    log::debug!("router: worker {w} failed, re-routing: {e}");
                    upstreams.remove(&w);
                    if wrote {
                        break;
                    }
                }
            }
        }
        if !answered {
            write_line(&mut writer, &error_line(id.as_ref(), "no reachable worker"))?;
        }
    }
    Ok(())
}

/// Decide the candidate order for one request (preferred first) and stamp
/// affinity-placed generations.
fn route(
    shared: &Shared,
    op: &str,
    mut req: Value,
    upstreams: &mut HashMap<usize, Client>,
) -> (Vec<usize>, Value) {
    let n = shared.cfg.workers.len();
    let rr_from = |start: usize| (0..n).map(|i| (start + i) % n).collect::<Vec<_>>();
    let ns = req
        .opt("ns")
        .and_then(|s| s.as_str().ok())
        .and_then(|s| Namespace::new(s).ok())
        .unwrap_or_default();
    if shared.cfg.mode == RouteMode::Affinity {
        // Uploads: the ring owner, deterministically.
        let seg = match op {
            "upload" | "add_reference" => req
                .opt("handle")
                .and_then(|h| h.as_str().ok())
                .map(|h| SegmentId::Image(ImageId::from_handle(h))),
            "chunk.upload" => req
                .opt("handle")
                .and_then(|h| h.as_str().ok())
                .map(|h| SegmentId::Chunk(ChunkId::from_handle(h))),
            _ => None,
        };
        if let Some(seg) = seg {
            return (rr_from(shared.ring.owner(&ns, seg)), req);
        }
        // Generations: probe residency of the prompt's reuse spans.
        if op == "infer" || op == "chat" {
            let spans = req
                .opt("text")
                .and_then(|t| t.as_str().ok())
                .map(|t| Prompt::parse(UserId(0), t).segment_ids())
                .unwrap_or_default();
            if !spans.is_empty() {
                let bitmaps = probe_workers(shared, &ns, &spans, upstreams);
                let scores = affinity_scores(spans.len(), &bitmaps);
                let occupancy = shared.occupancy.lock().clone();
                let winner = choose_worker(&scores, &occupancy);
                if scores[winner] > 0 {
                    req.set("routed", Value::str("affinity"));
                }
                // Failover order: by descending score, winner first.
                let mut order = rr_from(winner);
                order.sort_by_key(|&w| (w != winner, std::cmp::Reverse(scores[w])));
                return (order, req);
            }
        }
    }
    (rr_from(shared.rr.fetch_add(1, Ordering::Relaxed) % n), req)
}

/// One `kv.probe` per worker over the request's spans. A worker that
/// cannot be probed scores an all-false bitmap (it can still serve the
/// request as a failover candidate).
fn probe_workers(
    shared: &Shared,
    ns: &Namespace,
    spans: &[SegmentId],
    upstreams: &mut HashMap<usize, Client>,
) -> Vec<Vec<bool>> {
    let keys = Value::arr(
        spans
            .iter()
            .map(|&seg| {
                let kind = match seg {
                    SegmentId::Image(_) => "image",
                    SegmentId::Chunk(_) => "chunk",
                };
                let mut k = Value::obj(vec![
                    ("kind", Value::str(kind)),
                    ("segment", Value::str(format!("{:016x}", seg.raw()))),
                ]);
                if !ns.is_default() {
                    k.set("ns", Value::str(ns.as_str()));
                }
                k
            })
            .collect(),
    );
    let req = Value::obj(vec![
        ("v", Value::num(3.0)),
        ("op", Value::str("kv.probe")),
        ("id", Value::str("route")),
        ("keys", keys),
    ]);
    (0..shared.cfg.workers.len())
        .map(|w| match probe_one(shared, w, &req, upstreams) {
            Ok(bm) => bm,
            Err(e) => {
                log::debug!("router: probe of worker {w} failed: {e}");
                upstreams.remove(&w);
                vec![false; spans.len()]
            }
        })
        .collect()
}

/// One probe round-trip against one worker, under the probe deadline.
fn probe_one(
    shared: &Shared,
    w: usize,
    req: &Value,
    upstreams: &mut HashMap<usize, Client>,
) -> Result<Vec<bool>> {
    let c = upstream(shared, w, upstreams)?;
    c.set_read_deadline(Some(shared.cfg.probe_timeout))?;
    let resp = c.call(req);
    c.set_read_deadline(None)?;
    let resp = resp?;
    anyhow::ensure!(resp.get("ok")?.as_bool()?, "probe rejected");
    Ok(resp.get("bitmap")?.as_arr()?.iter().map(|b| b.as_bool().unwrap_or(false)).collect())
}

/// The cached upstream client for worker `w`, connecting if needed.
fn upstream<'a>(
    shared: &Shared,
    w: usize,
    upstreams: &'a mut HashMap<usize, Client>,
) -> Result<&'a mut Client> {
    match upstreams.entry(w) {
        std::collections::hash_map::Entry::Occupied(e) => Ok(e.into_mut()),
        std::collections::hash_map::Entry::Vacant(e) => {
            let mut c = Client::connect_timeout(shared.cfg.workers[w], shared.cfg.probe_timeout)?;
            // Forwarded generations stream with unbounded decode gaps.
            c.set_read_deadline(None)?;
            Ok(e.insert(c))
        }
    }
}

/// Forward one request to worker `w` and proxy every reply line verbatim
/// until the terminal (non-chunk) line.
fn forward(
    shared: &Shared,
    w: usize,
    upstreams: &mut HashMap<usize, Client>,
    req: &Value,
    writer: &mut TcpStream,
    wrote: &mut bool,
) -> Result<()> {
    let c = upstream(shared, w, upstreams)?;
    c.send(req)?;
    loop {
        let line = c.recv().map_err(|e| {
            if e.downcast_ref::<PeerUnreachable>().is_some() {
                e
            } else {
                e.context(format!("worker {w} reply stream"))
            }
        })?;
        write_line(writer, &line)?;
        *wrote = true;
        let is_chunk = line.opt("stream").and_then(|s| s.as_bool().ok()).unwrap_or(false);
        if !is_chunk {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A scripted worker: answers `kv.probe` with a fixed bitmap and every
    /// other op with `{ok, id, worker: idx}` (+ an optional leading chunk
    /// line), so tests can see *which* worker served and that chunk lines
    /// proxy through.
    fn fake_worker(idx: usize, resident: Vec<bool>, chunk_first: bool) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let resident = resident.clone();
                std::thread::spawn(move || {
                    let mut w = stream.try_clone().unwrap();
                    let r = BufReader::new(stream);
                    for line in r.lines() {
                        let Ok(line) = line else { break };
                        let req = Value::parse(&line).unwrap();
                        let id = req.opt("id").cloned().unwrap_or(Value::Null);
                        let op = req.opt("op").and_then(|o| o.as_str().ok()).unwrap_or("");
                        let mut out = String::new();
                        if op == "kv.probe" {
                            let n = req.get("keys").unwrap().as_arr().unwrap().len();
                            let bits: Vec<Value> = (0..n)
                                .map(|i| Value::Bool(resident.get(i).copied().unwrap_or(false)))
                                .collect();
                            let resp = Value::obj(vec![
                                ("ok", Value::Bool(true)),
                                ("id", id),
                                ("bitmap", Value::arr(bits)),
                            ]);
                            out.push_str(&resp.encode());
                            out.push('\n');
                        } else {
                            if chunk_first && op == "infer" {
                                let chunk = Value::obj(vec![
                                    ("ok", Value::Bool(true)),
                                    ("id", id.clone()),
                                    ("stream", Value::Bool(true)),
                                    ("seq", Value::num(0.0)),
                                ]);
                                out.push_str(&chunk.encode());
                                out.push('\n');
                            }
                            let mut resp = Value::obj(vec![
                                ("ok", Value::Bool(true)),
                                ("id", id),
                                ("worker", Value::num(idx as f64)),
                            ]);
                            if let Some(routed) = req.opt("routed") {
                                resp.set("routed_seen", routed.clone());
                            }
                            if let Some(t) = req.opt("trace") {
                                resp.set("trace_seen", t.clone());
                            }
                            out.push_str(&resp.encode());
                            out.push('\n');
                        }
                        if w.write_all(out.as_bytes()).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        addr
    }

    fn start_router(cfg: RouterConfig) -> SocketAddr {
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            serve_router(cfg, "127.0.0.1:0", |a| tx.send(a).unwrap()).unwrap();
        });
        rx.recv().unwrap()
    }

    fn fast_cfg(workers: Vec<SocketAddr>) -> RouterConfig {
        RouterConfig {
            workers,
            mode: RouteMode::Affinity,
            probe_timeout: Duration::from_millis(300),
            stats_interval: Duration::from_millis(60_000), // poller idle in tests
            metrics_addr: None,
        }
    }

    #[test]
    fn generation_routes_to_span_owner_and_is_stamped() {
        // Worker 1 owns the span; the reply must come from worker 1 and
        // the forwarded request must carry the affinity stamp.
        let w0 = fake_worker(0, vec![false], false);
        let w1 = fake_worker(1, vec![true], true);
        let router = start_router(fast_cfg(vec![w0, w1]));
        let mut c = Client::connect(router).unwrap();
        let req = Value::parse(
            r#"{"v":3,"id":"g","op":"infer","user":1,"text":"describe IMAGE#A","stream":true}"#,
        )
        .unwrap();
        let mut chunks = 0;
        let done = c.call_stream(&req, |_| chunks += 1).unwrap();
        assert_eq!(done.get("worker").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(done.get("routed_seen").unwrap().as_str().unwrap(), "affinity");
        assert_eq!(chunks, 1, "chunk lines must proxy through verbatim");
        // A spanless op round-robins and is never stamped.
        let stats = c
            .call(&Value::parse(r#"{"op":"infer","id":"s","user":1,"text":"hello"}"#).unwrap())
            .unwrap();
        assert!(stats.opt("routed_seen").is_none());
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }

    #[test]
    fn uploads_land_on_the_ring_owner_deterministically() {
        let w0 = fake_worker(0, vec![], false);
        let w1 = fake_worker(1, vec![], false);
        let router = start_router(fast_cfg(vec![w0, w1]));
        let ring = HashRing::new(2);
        let mut c = Client::connect(router).unwrap();
        for handle in ["IMAGE#A", "IMAGE#B", "IMAGE#C", "IMAGE#D"] {
            let seg = SegmentId::Image(ImageId::from_handle(handle));
            let want = ring.owner(&Namespace::default(), seg);
            let req = Value::obj(vec![
                ("op", Value::str("upload")),
                ("id", Value::str(handle)),
                ("user", Value::num(1.0)),
                ("handle", Value::str(handle)),
            ]);
            let resp = c.call(&req).unwrap();
            assert_eq!(
                resp.get("worker").unwrap().as_f64().unwrap(),
                want as f64,
                "upload {handle} must land on its ring owner"
            );
        }
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }

    #[test]
    fn generations_get_a_trace_id_minted_if_absent() {
        let w0 = fake_worker(0, vec![false], false);
        let router = start_router(fast_cfg(vec![w0]));
        let mut c = Client::connect(router).unwrap();
        let resp = c
            .call(&Value::parse(r#"{"op":"infer","id":"t","user":1,"text":"hello"}"#).unwrap())
            .unwrap();
        let minted = resp.get("trace_seen").unwrap().as_str().unwrap().to_string();
        assert!(
            minted.len() == 16 && minted.chars().all(|ch| ch.is_ascii_hexdigit()),
            "minted trace must be 16 hex digits: {minted}"
        );
        // A client-supplied id forwards untouched; non-generations are
        // never stamped.
        let resp = c
            .call(
                &Value::parse(
                    r#"{"op":"infer","id":"t2","user":1,"text":"hello","trace":"00000000deadbeef"}"#,
                )
                .unwrap(),
            )
            .unwrap();
        assert_eq!(resp.get("trace_seen").unwrap().as_str().unwrap(), "00000000deadbeef");
        let resp = c.call(&Value::parse(r#"{"op":"ping","id":"p"}"#).unwrap()).unwrap();
        assert!(resp.opt("trace_seen").is_none());
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }

    #[test]
    fn stats_cluster_surfaces_per_worker_reachability() {
        let w0 = fake_worker(0, vec![], false);
        let router = start_router(fast_cfg(vec![w0]));
        let mut c = Client::connect(router).unwrap();
        let resp = c.call(&Value::parse(r#"{"op":"stats.cluster","id":"sc"}"#).unwrap()).unwrap();
        assert!(resp.get("ok").unwrap().as_bool().unwrap());
        let workers = resp.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 1);
        assert!(workers[0].get("addr").is_ok());
        // The scripted worker's `stats` reply carries no metrics tree, so
        // it reports as unreadable instead of poisoning the aggregate.
        assert!(!workers[0].get("ok").unwrap().as_bool().unwrap());
        assert_eq!(resp.get("metrics").unwrap().get("workers").unwrap().as_f64().unwrap(), 0.0);
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }

    #[test]
    fn aggregate_sums_counters_and_merges_histograms() {
        let snap = |reqs: f64, bucket0: f64| {
            Value::obj(vec![
                ("requests", Value::num(reqs)),
                ("tokens_out", Value::num(reqs * 3.0)),
                ("uptime_s", Value::num(reqs)),
                ("kv", Value::obj(vec![("device_hits", Value::num(reqs))])),
                (
                    "histograms",
                    Value::obj(vec![(
                        "ttft_s",
                        Value::obj(vec![
                            ("le", Value::arr(vec![Value::num(0.001), Value::num(0.01)])),
                            ("counts", Value::arr(vec![Value::num(bucket0), Value::num(1.0)])),
                            ("sum", Value::num(0.5)),
                            ("count", Value::num(bucket0 + 1.0)),
                        ]),
                    )]),
                ),
            ])
        };
        let agg = aggregate_snapshots(&[snap(2.0, 1.0), snap(5.0, 3.0)]);
        assert_eq!(agg.get("workers").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(agg.get("requests").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(agg.get("tokens_out").unwrap().as_f64().unwrap(), 21.0);
        assert_eq!(agg.get("uptime_s").unwrap().as_f64().unwrap(), 5.0, "uptime is max, not sum");
        assert_eq!(agg.get("kv").unwrap().get("device_hits").unwrap().as_f64().unwrap(), 7.0);
        let h = agg.get("histograms").unwrap().get("ttft_s").unwrap();
        let counts = h.get("counts").unwrap().as_arr().unwrap();
        assert_eq!(counts[0].as_f64().unwrap(), 4.0);
        assert_eq!(counts[1].as_f64().unwrap(), 2.0);
        assert_eq!(h.get("count").unwrap().as_f64().unwrap(), 6.0);
        // The aggregate renders through the same exposition path a worker
        // snapshot does.
        let text = crate::coordinator::metrics::prometheus_from_snapshot(&agg);
        assert!(text.contains("mpic_requests_total 7\n"), "{text}");
        assert!(text.contains("mpic_ttft_seconds_bucket{le=\"+Inf\"} 6\n"), "{text}");
    }

    #[test]
    fn dead_worker_re_routes_to_next_candidate() {
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let live = fake_worker(1, vec![false], false);
        // Round-robin mode so every request starts from the rr cursor —
        // some of them will prefer the dead worker first.
        let mut cfg = fast_cfg(vec![dead, live]);
        cfg.mode = RouteMode::RoundRobin;
        let router = start_router(cfg);
        let mut c = Client::connect(router).unwrap();
        for i in 0..4 {
            let req = Value::obj(vec![
                ("op", Value::str("ping")),
                ("id", Value::str(format!("p{i}"))),
            ]);
            let resp = c.call(&req).unwrap();
            assert!(resp.get("ok").unwrap().as_bool().unwrap(), "re-route must succeed: {resp:?}");
            assert_eq!(resp.get("worker").unwrap().as_f64().unwrap(), 1.0);
        }
        let _ = c.call(&Value::parse(r#"{"op":"shutdown","id":"x"}"#).unwrap());
    }
}
