//! `PeerTransport` — the remote tier behind [`crate::kv::Transport`].
//!
//! Speaks the worker wire protocol's peer KV lane: `kv.probe` for a
//! residency bitmap, `kv.pull` for the base64-framed container. The
//! container bytes cross the wire exactly as they sit on the serving
//! worker's disk — framing is the only transformation, there is no
//! decode/re-encode cycle on the sender. A pull may carry an optional
//! `groups` field: the peer then serves only the self-contained prefix
//! of the v5 container covering the first `groups` layer groups, which
//! the streamed fetch path splices into prefill while the full pull is
//! still in flight.
//!
//! Failure posture (a flapping peer must cost latency once, never stall
//! prefill):
//!
//! * every connect and read carries [`PeerConfig::timeout`];
//! * one retry with backoff per pull, then the peer is marked dead for
//!   [`PeerConfig::dead_ttl`];
//! * negative probes are cached for [`PeerConfig::negative_ttl`], so a
//!   repeated miss does not re-probe every request.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context};

use crate::coordinator::metrics::ClusterCounters;
use crate::kv::{KvKey, Transport};
use crate::mm::{ChunkId, ImageId, Namespace, SegmentId};
use crate::server::Client;
use crate::util::json::Value;
use crate::util::sync::{LockRank, OrderedMutex};
use crate::util::trace;
use crate::Result;

/// Tunables for the peer lane.
#[derive(Debug, Clone)]
pub struct PeerConfig {
    /// Connect + read deadline per peer call.
    pub timeout: Duration,
    /// Backoff before the single pull retry.
    pub retry_backoff: Duration,
    /// How long a negative probe (peer does not have the key) is trusted.
    pub negative_ttl: Duration,
    /// How long a peer that failed twice is skipped entirely.
    pub dead_ttl: Duration,
}

impl Default for PeerConfig {
    fn default() -> Self {
        PeerConfig {
            timeout: Duration::from_millis(500),
            retry_backoff: Duration::from_millis(100),
            negative_ttl: Duration::from_secs(2),
            dead_ttl: Duration::from_secs(2),
        }
    }
}

/// Serialise one key for the `kv.probe`/`kv.pull` wire: kind + zero-padded
/// hex id + (non-default) namespace. The model travels once per request.
pub fn key_to_wire(key: &KvKey) -> Value {
    let kind = match key.seg {
        SegmentId::Image(_) => "image",
        SegmentId::Chunk(_) => "chunk",
    };
    let mut v = Value::obj(vec![
        ("kind", Value::str(kind)),
        ("segment", Value::str(format!("{:016x}", key.seg.raw()))),
    ]);
    if !key.ns.is_default() {
        v.set("ns", Value::str(key.ns.as_str()));
    }
    v
}

/// Parse one wire key back into a [`KvKey`] under the given model (the
/// serving side of [`key_to_wire`]).
pub fn wire_to_key(model: &str, v: &Value) -> Result<KvKey> {
    let kind = v.get("kind")?.as_str()?.to_string();
    let raw = u64::from_str_radix(v.get("segment")?.as_str()?, 16)
        .context("bad segment hex in wire key")?;
    let seg = match kind.as_str() {
        "image" => SegmentId::Image(ImageId(raw)),
        "chunk" => SegmentId::Chunk(ChunkId(raw)),
        other => return Err(anyhow!("unknown segment kind {other:?}")),
    };
    let ns = match v.opt("ns").and_then(|n| n.as_str().ok()) {
        Some(s) if !s.is_empty() => Namespace::new(s)?,
        _ => Namespace::default(),
    };
    Ok(KvKey::segment(model, &ns, seg))
}

/// One peer round-trip failure, typed so callers can tell a peer that
/// is *down* from one that answered protocol garbage: an unreachable
/// peer is worth retrying after its cooldown, a malformed reply fails
/// identically every time and is never worth an immediate retry.
#[derive(Debug)]
pub enum PeerError {
    /// Connect or read failed/timed out — the peer may be down.
    Unreachable { peer: SocketAddr, source: anyhow::Error },
    /// The peer answered, but the reply violated the protocol (missing
    /// or ill-typed field, short bitmap, bad frame, rejection).
    Decode { peer: SocketAddr, what: &'static str, source: anyhow::Error },
}

impl std::fmt::Display for PeerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PeerError::Unreachable { peer, source } => {
                write!(f, "peer {peer} unreachable: {source}")
            }
            PeerError::Decode { peer, what, source } => {
                write!(f, "peer {peer} sent an undecodable {what}: {source}")
            }
        }
    }
}

impl std::error::Error for PeerError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PeerError::Unreachable { source, .. } | PeerError::Decode { source, .. } => {
                Some(&**source)
            }
        }
    }
}

type PeerResult<T> = std::result::Result<T, PeerError>;

/// Strict decode of a `kv.probe` reply. The old parser defaulted
/// non-bool bitmap bits to `false`, which silently turned a malformed
/// peer into permanent misses; now any ill-typed field is a
/// [`PeerError::Decode`].
fn decode_probe_reply(peer: SocketAddr, resp: &Value, n: usize) -> PeerResult<Vec<bool>> {
    let decode = |source: anyhow::Error| PeerError::Decode { peer, what: "kv.probe reply", source };
    if !resp.get("ok").and_then(|v| v.as_bool()).map_err(&decode)? {
        return Err(decode(anyhow!("rejected: {}", resp.encode())));
    }
    let arr = resp.get("bitmap").and_then(|v| v.as_arr()).map_err(&decode)?;
    let mut bitmap = Vec::with_capacity(arr.len());
    for b in arr {
        bitmap.push(b.as_bool().map_err(&decode)?);
    }
    if bitmap.len() != n {
        return Err(decode(anyhow!("bitmap has {} of {n} bits", bitmap.len())));
    }
    Ok(bitmap)
}

/// Strict decode of a `kv.pull` reply: a well-formed `not_found` miss
/// is `Ok(None)`; every other rejection or ill-typed field is a
/// [`PeerError::Decode`].
fn decode_pull_reply(peer: SocketAddr, resp: &Value) -> PeerResult<Option<Vec<u8>>> {
    let decode = |source: anyhow::Error| PeerError::Decode { peer, what: "kv.pull reply", source };
    if !resp.get("ok").and_then(|v| v.as_bool()).map_err(&decode)? {
        match resp.opt("code").map(|c| c.as_str()) {
            Some(Ok("not_found")) => return Ok(None),
            _ => return Err(decode(anyhow!("rejected: {}", resp.encode()))),
        }
    }
    let frame = resp.get("frame").and_then(|v| v.as_str()).map_err(&decode)?;
    let bytes = crate::kv::codec::unframe(frame).map_err(&decode)?;
    Ok(Some(bytes))
}

/// The peer-to-peer KV transport: a list of worker addresses tried in
/// key-rotated order, with timeouts, retry, and probe caching.
pub struct PeerTransport {
    peers: Vec<SocketAddr>,
    cfg: PeerConfig,
    counters: Arc<ClusterCounters>,
    /// `(peer, key) → trusted-until` for probes that came back negative.
    /// Ranked `Transfer#2`; never held together with `dead_until`.
    negative: OrderedMutex<HashMap<(SocketAddr, KvKey), Instant>>,
    /// `peer → skip-until` for peers that failed connect/call twice
    /// (`Transfer#3`).
    dead_until: OrderedMutex<HashMap<SocketAddr, Instant>>,
}

impl PeerTransport {
    pub fn new(
        peers: Vec<SocketAddr>,
        cfg: PeerConfig,
        counters: Arc<ClusterCounters>,
    ) -> PeerTransport {
        PeerTransport {
            peers,
            cfg,
            counters,
            negative: OrderedMutex::with_index(LockRank::Transfer, 2, HashMap::new()),
            dead_until: OrderedMutex::with_index(LockRank::Transfer, 3, HashMap::new()),
        }
    }

    pub fn peers(&self) -> &[SocketAddr] {
        &self.peers
    }

    fn peer_dead(&self, peer: SocketAddr) -> bool {
        let now = Instant::now();
        let mut g = self.dead_until.lock();
        match g.get(&peer) {
            Some(&until) if until > now => true,
            Some(_) => {
                g.remove(&peer);
                false
            }
            None => false,
        }
    }

    fn mark_dead(&self, peer: SocketAddr) {
        self.counters.peer_timeouts.fetch_add(1, Ordering::Relaxed);
        self.dead_until.lock().insert(peer, Instant::now() + self.cfg.dead_ttl);
    }

    fn negative_cached(&self, peer: SocketAddr, key: &KvKey) -> bool {
        let now = Instant::now();
        let mut g = self.negative.lock();
        match g.get(&(peer, key.clone())) {
            Some(&until) if until > now => true,
            Some(_) => {
                g.remove(&(peer, key.clone()));
                false
            }
            None => false,
        }
    }

    fn cache_negative(&self, peer: SocketAddr, key: &KvKey) {
        let mut g = self.negative.lock();
        // Bound the cache: prune lapsed entries once it grows.
        if g.len() > 4096 {
            let now = Instant::now();
            g.retain(|_, &mut until| until > now);
        }
        g.insert((peer, key.clone()), Instant::now() + self.cfg.negative_ttl);
    }

    /// One `kv.probe` round-trip against one peer.
    fn probe_peer(&self, peer: SocketAddr, keys: &[KvKey]) -> PeerResult<Vec<bool>> {
        let t0 = Instant::now();
        let unreachable = |source: anyhow::Error| PeerError::Unreachable { peer, source };
        let mut c = Client::connect_timeout(peer, self.cfg.timeout).map_err(&unreachable)?;
        self.counters.peer_probes.fetch_add(1, Ordering::Relaxed);
        let mut req = Value::obj(vec![
            ("v", Value::num(3.0)),
            ("op", Value::str("kv.probe")),
            ("id", Value::str(format!("probe-{}", std::process::id()))),
            ("model", Value::str(keys[0].model.as_str())),
            ("keys", Value::arr(keys.iter().map(key_to_wire).collect())),
        ]);
        // Propagate the caller's trace id across the wire so the serving
        // peer's flight recorder can attribute the work.
        if let Some(t) = trace::current() {
            req.set("trace", Value::str(t.hex()));
        }
        let resp = c.call(&req).map_err(&unreachable)?;
        trace::record(
            "peer_probe",
            t0,
            &[
                ("peer", Value::str(peer.to_string())),
                ("keys", Value::num(keys.len() as f64)),
            ],
        );
        decode_probe_reply(peer, &resp, keys.len())
    }

    /// One `kv.pull` round-trip (no retry here; `pull_impl` owns the
    /// retry). `groups = Some(g)` asks the peer for only the first `g`
    /// layer groups of the container.
    fn pull_peer(
        &self,
        peer: SocketAddr,
        key: &KvKey,
        groups: Option<usize>,
    ) -> PeerResult<Option<Vec<u8>>> {
        let t0 = Instant::now();
        let unreachable = |source: anyhow::Error| PeerError::Unreachable { peer, source };
        let mut c = Client::connect_timeout(peer, self.cfg.timeout).map_err(&unreachable)?;
        let mut req = Value::obj(vec![
            ("v", Value::num(3.0)),
            ("op", Value::str("kv.pull")),
            ("id", Value::str(format!("pull-{}", std::process::id()))),
            ("model", Value::str(key.model.as_str())),
        ]);
        if let Some(g) = groups {
            req.set("groups", Value::num(g as f64));
        }
        if let Some(t) = trace::current() {
            req.set("trace", Value::str(t.hex()));
        }
        // Flatten the key fields into the envelope (single-key op).
        if let (Value::Obj(req_m), Value::Obj(key_m)) = (&mut req, key_to_wire(key)) {
            req_m.extend(key_m);
        }
        let resp = c.call(&req).map_err(&unreachable)?;
        let Some(bytes) = decode_pull_reply(peer, &resp)? else {
            return Ok(None);
        };
        self.counters.peer_pulls.fetch_add(1, Ordering::Relaxed);
        self.counters.peer_pull_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        trace::record(
            "peer_pull",
            t0,
            &[
                ("peer", Value::str(peer.to_string())),
                ("bytes", Value::num(bytes.len() as f64)),
                ("groups", Value::num(groups.map(|g| g as f64).unwrap_or(-1.0))),
            ],
        );
        Ok(Some(bytes))
    }

    /// Shared body of [`Transport::pull`] / [`Transport::pull_range`]:
    /// probe-gated pull with one retry, walking peers in key-rotated order.
    fn pull_impl(&self, key: &KvKey, groups: Option<usize>) -> Result<Option<Vec<u8>>> {
        for peer in self.peer_order(key) {
            if self.peer_dead(peer) || self.negative_cached(peer, key) {
                continue;
            }
            // Probe first: a pull moves megabytes, a probe moves a line.
            match self.probe_peer(peer, std::slice::from_ref(key)) {
                Ok(bitmap) if !bitmap[0] => {
                    self.cache_negative(peer, key);
                    continue;
                }
                Ok(_) => {}
                Err(e) => {
                    log::debug!("cluster: probe of {peer} failed: {e}");
                    self.mark_dead(peer);
                    continue;
                }
            }
            // Pull, with one retry after backoff (the peer just answered
            // the probe, so a transient hiccup is worth one more try).
            // A malformed reply fails the same way every time, so it
            // skips the retry and cools the peer down immediately.
            for attempt in 0..2 {
                match self.pull_peer(peer, key, groups) {
                    Ok(got) => return Ok(got),
                    Err(e @ PeerError::Decode { .. }) => {
                        log::warn!("cluster: {e}");
                        self.mark_dead(peer);
                        break;
                    }
                    Err(e) if attempt == 0 => {
                        log::debug!("cluster: pull from {peer} failed (will retry): {e}");
                        std::thread::sleep(self.cfg.retry_backoff);
                    }
                    Err(e) => {
                        log::debug!("cluster: pull from {peer} failed twice: {e}");
                        self.mark_dead(peer);
                    }
                }
            }
        }
        Ok(None)
    }

    /// Rotate the peer order by key so different keys spread their first
    /// choice across the cluster instead of hammering peer 0.
    fn peer_order(&self, key: &KvKey) -> impl Iterator<Item = SocketAddr> + '_ {
        let n = self.peers.len();
        let start = if n == 0 {
            0
        } else {
            (crate::util::rng::fnv1a(&key.seg.raw().to_le_bytes()) % n as u64) as usize
        };
        (0..n).map(move |i| self.peers[(start + i) % n])
    }
}

impl Transport for PeerTransport {
    fn probe(&self, keys: &[KvKey]) -> Vec<bool> {
        let mut out = vec![false; keys.len()];
        if keys.is_empty() {
            return out;
        }
        for &peer in &self.peers {
            if self.peer_dead(peer) {
                continue;
            }
            match self.probe_peer(peer, keys) {
                Ok(bitmap) => {
                    for (slot, bit) in out.iter_mut().zip(&bitmap) {
                        *slot |= bit;
                    }
                }
                Err(e) => {
                    log::debug!("cluster: probe of {peer} failed: {e}");
                    self.mark_dead(peer);
                }
            }
        }
        out
    }

    fn pull(&self, key: &KvKey) -> Result<Option<Vec<u8>>> {
        self.pull_impl(key, None)
    }

    fn pull_range(&self, key: &KvKey, groups: Option<usize>) -> Result<Option<Vec<u8>>> {
        self.pull_impl(key, groups)
    }

    fn name(&self) -> &'static str {
        "peer"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kv::test_entry;
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpListener;

    fn counters() -> Arc<ClusterCounters> {
        Arc::new(ClusterCounters::default())
    }

    fn fast_cfg() -> PeerConfig {
        PeerConfig {
            timeout: Duration::from_millis(200),
            retry_backoff: Duration::from_millis(10),
            negative_ttl: Duration::from_millis(500),
            dead_ttl: Duration::from_millis(500),
        }
    }

    #[test]
    fn wire_key_roundtrip() {
        let ns = Namespace::new("tenant-a").unwrap();
        for key in [
            KvKey::image("m", ImageId(7)),
            KvKey::chunk("m", ChunkId(u64::MAX)),
            KvKey::segment("m", &ns, SegmentId::Image(ImageId(0))),
        ] {
            let back = wire_to_key("m", &key_to_wire(&key)).unwrap();
            assert_eq!(back, key);
        }
        assert!(wire_to_key("m", &Value::obj(vec![("kind", Value::str("blob"))])).is_err());
    }

    /// A scripted single-threaded fake worker: answers `kv.probe` with the
    /// given bitmap and `kv.pull` with the given frame, over the real
    /// JSON-lines protocol. No engine, no artifacts.
    fn fake_worker(resident: bool, container: Option<Vec<u8>>) -> SocketAddr {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let mut writer = stream.try_clone().unwrap();
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let Ok(line) = line else { break };
                    let req = Value::parse(&line).unwrap();
                    let op = req.get("op").unwrap().as_str().unwrap().to_string();
                    let id = req.get("id").unwrap().clone();
                    let resp = match op.as_str() {
                        "kv.probe" => {
                            let n = req.get("keys").unwrap().as_arr().unwrap().len();
                            Value::obj(vec![
                                ("ok", Value::Bool(true)),
                                ("id", id),
                                ("bitmap", Value::arr(vec![Value::Bool(resident); n])),
                            ])
                        }
                        "kv.pull" => match &container {
                            Some(bytes) => {
                                // Honour a `groups` range the way a real
                                // worker does: serve the self-contained v5
                                // prefix covering the first `g` groups.
                                let mut served = bytes.clone();
                                let mut n_groups = 0usize;
                                if let Ok(info) = crate::kv::codec::parse_container(bytes) {
                                    n_groups = info.n_groups();
                                    if let Some(g) =
                                        req.opt("groups").and_then(|v| v.as_f64().ok())
                                    {
                                        let g = (g as usize).clamp(1, n_groups.max(1));
                                        served.truncate(info.prefix_len(g));
                                    }
                                }
                                Value::obj(vec![
                                    ("ok", Value::Bool(true)),
                                    ("id", id),
                                    ("frame", Value::str(crate::kv::codec::frame(&served))),
                                    ("bytes", Value::num(served.len() as f64)),
                                    ("n_groups", Value::num(n_groups as f64)),
                                ])
                            }
                            None => Value::obj(vec![
                                ("ok", Value::Bool(false)),
                                ("id", id),
                                ("code", Value::str("not_found")),
                                ("error", Value::str("no such entry")),
                            ]),
                        },
                        _ => Value::obj(vec![("ok", Value::Bool(false)), ("id", id)]),
                    };
                    writer.write_all(resp.encode().as_bytes()).unwrap();
                    writer.write_all(b"\n").unwrap();
                }
            }
        });
        addr
    }

    #[test]
    fn pulls_container_from_resident_peer() {
        let e = test_entry(11, 8);
        let container = crate::kv::codec::encode(&e).unwrap();
        let addr = fake_worker(true, Some(container.clone()));
        let ctr = counters();
        let t = PeerTransport::new(vec![addr], fast_cfg(), Arc::clone(&ctr));
        let got = t.pull(&e.key).unwrap().expect("peer had the container");
        assert_eq!(got, container);
        assert_eq!(ctr.peer_pulls.load(Ordering::Relaxed), 1);
        assert_eq!(ctr.peer_pull_bytes.load(Ordering::Relaxed), container.len() as u64);
        assert!(ctr.peer_probes.load(Ordering::Relaxed) >= 1);
        assert_eq!(ctr.peer_timeouts.load(Ordering::Relaxed), 0);
        assert_eq!(t.probe(std::slice::from_ref(&e.key)), vec![true]);
    }

    #[test]
    fn pull_range_serves_group_prefix() {
        use crate::kv::{KvShape, SegmentKv};
        let shape = KvShape { layers: 6, tokens: 8, heads: 2, d_head: 4, d_model: 8 };
        let mut rng = crate::util::rng::Rng::new(0x77);
        let e = SegmentKv {
            key: KvKey::image("m", ImageId(77)),
            shape,
            emb: (0..shape.emb_elems()).map(|_| rng.f32()).collect(),
            k: (0..shape.kv_elems()).map(|_| rng.f32()).collect(),
            v: (0..shape.kv_elems()).map(|_| rng.f32()).collect(),
        };
        let container = crate::kv::codec::encode(&e).unwrap();
        let info = crate::kv::codec::parse_container(&container).unwrap();
        assert!(info.n_groups() >= 3, "test needs a multi-group container");
        let addr = fake_worker(true, Some(container.clone()));
        let t = PeerTransport::new(vec![addr], fast_cfg(), counters());
        // A ranged pull returns exactly the self-contained one-group prefix...
        let prefix = t.pull_range(&e.key, Some(1)).unwrap().expect("peer had the container");
        assert_eq!(prefix, container[..info.prefix_len(1)].to_vec());
        let pinfo = crate::kv::codec::parse_container(&prefix).unwrap();
        assert_eq!(pinfo.groups_available(prefix.len()), 1);
        crate::kv::codec::decode_group(&pinfo, &prefix, 0).expect("prefix group decodes");
        // ...while an unbounded ranged pull and a plain pull return everything.
        assert_eq!(t.pull_range(&e.key, None).unwrap(), Some(container.clone()));
        assert_eq!(t.pull(&e.key).unwrap(), Some(container));
    }

    #[test]
    fn negative_probe_is_cached() {
        let addr = fake_worker(false, None);
        let ctr = counters();
        let t = PeerTransport::new(vec![addr], fast_cfg(), Arc::clone(&ctr));
        let key = KvKey::image("m", ImageId(1));
        assert!(t.pull(&key).unwrap().is_none());
        let probes_after_first = ctr.peer_probes.load(Ordering::Relaxed);
        assert_eq!(probes_after_first, 1);
        // Within the negative TTL the peer is not contacted again.
        assert!(t.pull(&key).unwrap().is_none());
        assert_eq!(ctr.peer_probes.load(Ordering::Relaxed), probes_after_first);
        // A different key probes fresh.
        assert!(t.pull(&KvKey::image("m", ImageId(2))).unwrap().is_none());
        assert_eq!(ctr.peer_probes.load(Ordering::Relaxed), probes_after_first + 1);
    }

    #[test]
    fn dead_peer_times_out_once_then_skips() {
        // A bound-but-dead port: the first pull pays the deadline and
        // marks the peer dead; the second returns immediately.
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let ctr = counters();
        let t = PeerTransport::new(vec![dead], fast_cfg(), Arc::clone(&ctr));
        let key = KvKey::image("m", ImageId(9));
        assert!(t.pull(&key).unwrap().is_none());
        assert_eq!(ctr.peer_timeouts.load(Ordering::Relaxed), 1);
        let t0 = Instant::now();
        assert!(t.pull(&key).unwrap().is_none());
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "dead peer must be skipped, not re-dialled"
        );
        assert_eq!(ctr.peer_timeouts.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn live_peer_beats_dead_peer() {
        let e = test_entry(21, 8);
        let container = crate::kv::codec::encode(&e).unwrap();
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let live = fake_worker(true, Some(container.clone()));
        let ctr = counters();
        let t = PeerTransport::new(vec![dead, live], fast_cfg(), ctr);
        assert_eq!(t.pull(&e.key).unwrap(), Some(container));
    }
}
