//! In-tree substrates (DESIGN.md §4, S1–S7).
//!
//! The build environment vendors only the `xla` crate's dependency closure,
//! so the usual ecosystem crates (serde, tokio, clap, criterion, proptest)
//! are unavailable; these modules provide the small, tested subset of their
//! functionality the system needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
pub mod trace;
