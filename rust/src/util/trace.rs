//! Request-scoped tracing and the flight recorder (substrate S8).
//!
//! MPIC's value claim is latency *decomposition* — fetch vs. link vs.
//! compute, local tier vs. peer pull — so every request carries a
//! [`TraceId`] and records named [`Span`]s as it moves through admission,
//! KV fetch, peer probe/pull, linking, selective-recompute prefill, decode
//! rounds and stream writes. The id travels on the wire (optional `"trace"`
//! envelope field), so a router-forwarded request and the peer `kv.pull`s
//! it triggers share one trace across the cluster.
//!
//! Three pieces, no external deps (matching the hand-rolled
//! [`crate::util::json`] style):
//!
//! - **[`Recorder`]** — per-process (one per [`crate::coordinator::Engine`])
//!   span sink. Active traces accumulate spans; finished traces move into a
//!   bounded ring buffer (the *flight recorder*) holding the last N for
//!   post-hoc inspection through the `debug.trace` wire op / `mpic trace`
//!   CLI. Any finished trace slower than the configured threshold
//!   (`--slow-ms`) is also emitted through the `log` facade at `warn`, so
//!   `MPIC_LOG=warn` surfaces slow requests with their span breakdown.
//! - **Thread-local scope** — [`Scope::enter`] pins a `(TraceId, Recorder)`
//!   pair to the current thread so deep layers (the transfer engine, a
//!   cluster [`crate::kv::Transport`]) can attribute spans to the request
//!   being served without threading a context argument through every
//!   signature. The engine thread serves one prefill/decode call at a time,
//!   which is exactly the granularity the scope guards.
//! - **[`Span`]** — name + `[start_us, start_us+dur_us]` offsets from the
//!   trace start, plus free-form attributes (`tier`, `bytes`, `peer`, ...).
//!   Spans render sorted by start offset, so a healthy trace reads
//!   monotonically: admission → fetch → peer pull → prefill → decode.
//!
//! Memory is bounded everywhere: the ring keeps `keep` traces, each trace
//! caps spans at [`MAX_SPANS`] (excess spans are counted, not stored).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::sync::{LockRank, OrderedMutex};
use std::time::{Duration, Instant};

use crate::util::json::Value;

/// Maximum spans retained per trace; later spans are counted as dropped.
pub const MAX_SPANS: usize = 512;

/// Default flight-recorder depth (completed traces retained).
pub const DEFAULT_KEEP: usize = 128;

/// A cluster-unique request trace id (rendered as 16 lowercase hex digits).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Generate a fresh id: process id, wall clock and a process-local
    /// counter hashed together — unique across the workers of a cluster
    /// without coordination.
    pub fn fresh() -> TraceId {
        static COUNTER: AtomicU64 = AtomicU64::new(1);
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let mut bytes = [0u8; 24];
        bytes[..8].copy_from_slice(&nanos.to_le_bytes());
        bytes[8..16].copy_from_slice(&(std::process::id() as u64).to_le_bytes());
        bytes[16..].copy_from_slice(&COUNTER.fetch_add(1, Ordering::Relaxed).to_le_bytes());
        let h = crate::util::rng::fnv1a(&bytes);
        TraceId(if h == 0 { 1 } else { h })
    }

    /// Parse the 16-hex-digit wire form.
    pub fn parse(s: &str) -> Option<TraceId> {
        if s.is_empty() || s.len() > 16 {
            return None;
        }
        u64::from_str_radix(s, 16).ok().filter(|&v| v != 0).map(TraceId)
    }

    /// Wire form: 16 lowercase hex digits.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.0)
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}", self.0)
    }
}

/// One timed, named interval inside a trace. Offsets are microseconds from
/// the trace start, so spans from different machines' clocks never mix.
#[derive(Debug, Clone)]
pub struct Span {
    pub name: String,
    pub start_us: u64,
    pub dur_us: u64,
    pub attrs: Vec<(String, Value)>,
}

impl Span {
    fn to_value(&self) -> Value {
        let mut v = Value::obj(vec![
            ("name", Value::str(&self.name)),
            ("start_us", Value::num(self.start_us as f64)),
            ("dur_us", Value::num(self.dur_us as f64)),
        ]);
        for (k, attr) in &self.attrs {
            v.set(k, attr.clone());
        }
        v
    }
}

#[derive(Debug)]
struct Trace {
    id: TraceId,
    op: String,
    started: Instant,
    spans: Vec<Span>,
    dropped_spans: u64,
    /// Set when the trace moves into the ring.
    total_us: Option<u64>,
}

impl Trace {
    fn to_value(&self) -> Value {
        let mut spans = self.spans.clone();
        spans.sort_by_key(|s| (s.start_us, s.start_us + s.dur_us));
        let mut v = Value::obj(vec![
            ("trace", Value::str(self.id.hex())),
            ("op", Value::str(&self.op)),
            ("done", Value::Bool(self.total_us.is_some())),
            (
                "total_us",
                Value::num(self.total_us.unwrap_or_else(|| {
                    spans.last().map(|s| s.start_us + s.dur_us).unwrap_or(0)
                }) as f64),
            ),
            ("spans", Value::arr(spans.iter().map(Span::to_value).collect())),
        ]);
        if self.dropped_spans > 0 {
            v.set("dropped_spans", Value::num(self.dropped_spans as f64));
        }
        v
    }
}

/// One row of [`Recorder::recent`].
#[derive(Debug, Clone)]
pub struct TraceSummary {
    pub id: TraceId,
    pub op: String,
    pub total_us: u64,
    pub n_spans: usize,
}

struct Inner {
    active: HashMap<u64, Trace>,
    /// Flight-recorder ring: completed traces, oldest first.
    done: VecDeque<Trace>,
    keep: usize,
    slow: Option<Duration>,
}

/// Span sink + flight recorder. One per engine; shared by reference with
/// the serving pipeline, the scheduler, and (through the thread-local
/// [`Scope`]) the transfer/transport layers.
pub struct Recorder {
    inner: OrderedMutex<Inner>,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new(DEFAULT_KEEP)
    }
}

impl Recorder {
    /// `keep`: flight-recorder depth (completed traces retained).
    pub fn new(keep: usize) -> Recorder {
        Recorder {
            inner: OrderedMutex::new(LockRank::Trace, Inner {
                active: HashMap::new(),
                done: VecDeque::new(),
                keep: keep.max(1),
                slow: None,
            }),
        }
    }

    /// Traces finishing slower than this are logged at `warn` with their
    /// span breakdown (`--slow-ms`); `None` disables the slow log.
    pub fn set_slow_threshold(&self, d: Option<Duration>) {
        self.inner.lock().slow = d;
    }

    pub fn slow_threshold(&self) -> Option<Duration> {
        self.inner.lock().slow
    }

    /// Open a trace. `start` anchors span offsets (pass the enqueue time so
    /// the admission-wait span starts at offset 0). Re-opening an already
    /// active id is a no-op, so a retried begin cannot clobber spans.
    pub fn begin_at(&self, id: TraceId, op: &str, start: Instant) {
        let mut g = self.inner.lock();
        g.active.entry(id.0).or_insert_with(|| Trace {
            id,
            op: op.to_string(),
            started: start,
            spans: Vec::new(),
            dropped_spans: 0,
            total_us: None,
        });
    }

    /// Append one span to an active trace; silently ignored when the id is
    /// not active (tracing must never fail a request).
    pub fn record(
        &self,
        id: TraceId,
        name: &str,
        start: Instant,
        end: Instant,
        attrs: &[(&str, Value)],
    ) {
        let mut g = self.inner.lock();
        let Some(t) = g.active.get_mut(&id.0) else { return };
        if t.spans.len() >= MAX_SPANS {
            t.dropped_spans += 1;
            return;
        }
        let start_us = start.saturating_duration_since(t.started).as_micros() as u64;
        let dur_us = end.saturating_duration_since(start).as_micros() as u64;
        t.spans.push(Span {
            name: name.to_string(),
            start_us,
            dur_us,
            attrs: attrs.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        });
    }

    /// Record a span on a trace this process never opened (e.g. a worker
    /// serving a peer's `kv.pull`): appends when the trace is active,
    /// otherwise files a single-span completed trace straight into the ring
    /// so remote legs of a cluster trace are inspectable on every hop.
    pub fn record_oneshot(
        &self,
        id: TraceId,
        op: &str,
        start: Instant,
        end: Instant,
        attrs: &[(&str, Value)],
    ) {
        {
            let g = self.inner.lock();
            if g.active.contains_key(&id.0) {
                drop(g);
                self.record(id, op, start, end, attrs);
                return;
            }
        }
        self.begin_at(id, op, start);
        self.record(id, op, start, end, attrs);
        self.finish(id);
    }

    /// Close a trace: move it into the flight-recorder ring (evicting the
    /// oldest entry past `keep`) and fire the slow-request log when it beat
    /// the threshold. Returns `(total_seconds, was_slow)`, or `None` when
    /// the id was not active.
    pub fn finish(&self, id: TraceId) -> Option<(f64, bool)> {
        let mut g = self.inner.lock();
        let mut t = g.active.remove(&id.0)?;
        let total = t.started.elapsed();
        t.total_us = Some(total.as_micros() as u64);
        let slow = g.slow.is_some_and(|thresh| total >= thresh);
        if slow {
            let mut parts: Vec<String> = t
                .spans
                .iter()
                .take(16)
                .map(|s| format!("{}:{:.1}ms", s.name, s.dur_us as f64 / 1e3))
                .collect();
            if t.spans.len() > 16 {
                parts.push(format!("(+{} spans)", t.spans.len() - 16));
            }
            log::warn!(
                target: "mpic::trace",
                "slow request trace={} op={} total={:.3}s spans=[{}]",
                t.id,
                t.op,
                total.as_secs_f64(),
                parts.join(" ")
            );
        }
        while g.done.len() >= g.keep {
            g.done.pop_front();
        }
        g.done.push_back(t);
        Some((total.as_secs_f64(), slow))
    }

    /// Completed traces, newest first.
    pub fn recent(&self) -> Vec<TraceSummary> {
        let g = self.inner.lock();
        g.done
            .iter()
            .rev()
            .map(|t| TraceSummary {
                id: t.id,
                op: t.op.clone(),
                total_us: t.total_us.unwrap_or(0),
                n_spans: t.spans.len(),
            })
            .collect()
    }

    /// One trace as structured JSON (completed traces first, then active
    /// ones, which render with `"done": false`).
    pub fn get(&self, id: TraceId) -> Option<Value> {
        let g = self.inner.lock();
        g.done
            .iter()
            .rev()
            .find(|t| t.id == id)
            .or_else(|| g.active.get(&id.0))
            .map(Trace::to_value)
    }
}

thread_local! {
    static CURRENT: std::cell::RefCell<Vec<(TraceId, Arc<Recorder>)>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// RAII guard pinning a trace to the current thread; see [`Scope::enter`].
pub struct Scope {
    _private: (),
}

impl Scope {
    /// Make `id` the current trace on this thread until the guard drops.
    /// Scopes nest (the previous trace is restored on drop).
    pub fn enter(id: TraceId, recorder: &Arc<Recorder>) -> Scope {
        CURRENT.with(|c| c.borrow_mut().push((id, Arc::clone(recorder))));
        Scope { _private: () }
    }
}

impl Drop for Scope {
    fn drop(&mut self) {
        CURRENT.with(|c| {
            c.borrow_mut().pop();
        });
    }
}

/// The trace pinned to this thread, if any.
pub fn current() -> Option<TraceId> {
    CURRENT.with(|c| c.borrow().last().map(|(id, _)| *id))
}

/// The trace pinned to this thread together with its recorder, if any.
/// Lets a caller hand the scope across a thread boundary (e.g. codec-pool
/// workers recording per-layer-group spans on the request's trace) where
/// the thread-local itself does not travel.
pub fn current_scope() -> Option<(TraceId, Arc<Recorder>)> {
    CURRENT.with(|c| c.borrow().last().map(|(id, rec)| (*id, Arc::clone(rec))))
}

/// Record a span `[start, now]` against the thread's current trace; no-op
/// when no trace is in scope (offline paths trace nothing, cost one TLS
/// read).
pub fn record(name: &str, start: Instant, attrs: &[(&str, Value)]) {
    CURRENT.with(|c| {
        if let Some((id, rec)) = c.borrow().last() {
            rec.record(*id, name, start, Instant::now(), attrs);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_id_roundtrip() {
        let id = TraceId::fresh();
        assert_ne!(id.0, 0);
        assert_eq!(TraceId::parse(&id.hex()), Some(id));
        assert_eq!(id.hex().len(), 16);
        assert_eq!(TraceId::parse("0"), None, "zero is reserved");
        assert_eq!(TraceId::parse(""), None);
        assert_eq!(TraceId::parse("zz"), None);
        assert_eq!(TraceId::parse("00000000000000000a"), None, "too long");
        assert_ne!(TraceId::fresh(), TraceId::fresh());
    }

    /// The flight recorder is a ring: oldest completed traces evict first,
    /// and `recent` lists newest-first.
    #[test]
    fn ring_buffer_eviction_order() {
        let rec = Recorder::new(2);
        let ids: Vec<TraceId> = (1..=3).map(TraceId).collect();
        for (i, id) in ids.iter().enumerate() {
            let t0 = Instant::now();
            rec.begin_at(*id, &format!("op{i}"), t0);
            rec.record(*id, "work", t0, Instant::now(), &[]);
            assert!(rec.finish(*id).is_some());
        }
        let recent = rec.recent();
        assert_eq!(recent.len(), 2, "ring holds only the last `keep` traces");
        assert_eq!(recent[0].id, ids[2], "newest first");
        assert_eq!(recent[1].id, ids[1]);
        assert!(rec.get(ids[0]).is_none(), "evicted trace is gone");
        assert!(rec.get(ids[1]).is_some());
        assert!(rec.finish(ids[0]).is_none(), "finish of unknown id is a no-op");
    }

    #[test]
    fn spans_render_sorted_with_attrs() {
        let rec = Recorder::new(4);
        let id = TraceId(7);
        let t0 = Instant::now();
        rec.begin_at(id, "infer", t0);
        let mid = t0 + Duration::from_millis(5);
        let late = t0 + Duration::from_millis(9);
        // Record out of order; rendering must sort by start offset.
        rec.record(id, "decode", late, late + Duration::from_millis(1), &[]);
        rec.record(id, "fetch", mid, late, &[("bytes", Value::num(42.0))]);
        rec.finish(id);
        let v = rec.get(id).unwrap();
        assert_eq!(v.get("op").unwrap().as_str().unwrap(), "infer");
        assert!(v.get("done").unwrap().as_bool().unwrap());
        let spans = v.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].get("name").unwrap().as_str().unwrap(), "fetch");
        assert_eq!(spans[0].get("bytes").unwrap().as_u64().unwrap(), 42);
        assert_eq!(spans[1].get("name").unwrap().as_str().unwrap(), "decode");
        assert!(
            spans[0].get("start_us").unwrap().as_u64().unwrap()
                <= spans[1].get("start_us").unwrap().as_u64().unwrap()
        );
    }

    #[test]
    fn span_cap_counts_drops() {
        let rec = Recorder::new(2);
        let id = TraceId(9);
        let t0 = Instant::now();
        rec.begin_at(id, "infer", t0);
        for _ in 0..(MAX_SPANS + 3) {
            rec.record(id, "s", t0, Instant::now(), &[]);
        }
        rec.finish(id);
        let v = rec.get(id).unwrap();
        assert_eq!(v.get("spans").unwrap().as_arr().unwrap().len(), MAX_SPANS);
        assert_eq!(v.get("dropped_spans").unwrap().as_u64().unwrap(), 3);
    }

    #[test]
    fn slow_threshold_marks_finish() {
        let rec = Recorder::new(2);
        let id = TraceId(11);
        rec.begin_at(id, "infer", Instant::now());
        rec.set_slow_threshold(Some(Duration::from_secs(3600)));
        let (_, slow) = rec.finish(id).unwrap();
        assert!(!slow, "an hour threshold cannot trip instantly");
        let id2 = TraceId(12);
        rec.begin_at(id2, "infer", Instant::now());
        rec.set_slow_threshold(Some(Duration::ZERO));
        let (total, slow) = rec.finish(id2).unwrap();
        assert!(slow, "zero threshold flags everything");
        assert!(total >= 0.0);
    }

    #[test]
    fn oneshot_files_completed_trace() {
        let rec = Arc::new(Recorder::new(4));
        let id = TraceId(21);
        let t0 = Instant::now();
        rec.record_oneshot(id, "kv.pull", t0, t0 + Duration::from_millis(2), &[]);
        let v = rec.get(id).unwrap();
        assert!(v.get("done").unwrap().as_bool().unwrap());
        assert_eq!(v.get("spans").unwrap().as_arr().unwrap().len(), 1);

        // When the trace is active locally, oneshot appends instead.
        let id2 = TraceId(22);
        rec.begin_at(id2, "infer", t0);
        rec.record_oneshot(id2, "kv.pull", t0, t0 + Duration::from_millis(1), &[]);
        assert_eq!(rec.recent().len(), 1, "active trace did not finish");
        rec.finish(id2);
        let v = rec.get(id2).unwrap();
        assert_eq!(v.get("spans").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn thread_local_scope_nests_and_clears() {
        let rec = Arc::new(Recorder::new(4));
        assert_eq!(current(), None);
        let id = TraceId(31);
        rec.begin_at(id, "infer", Instant::now());
        {
            let _g = Scope::enter(id, &rec);
            assert_eq!(current(), Some(id));
            {
                let inner = TraceId(32);
                rec.begin_at(inner, "nested", Instant::now());
                let _g2 = Scope::enter(inner, &rec);
                assert_eq!(current(), Some(inner));
                super::record("inner-span", Instant::now(), &[]);
                rec.finish(inner);
            }
            assert_eq!(current(), Some(id), "outer scope restored");
            super::record("outer-span", Instant::now(), &[]);
        }
        assert_eq!(current(), None);
        super::record("dropped", Instant::now(), &[]); // no scope: must not panic
        rec.finish(id);
        let spans = rec.get(id).unwrap();
        let spans = spans.get("spans").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("name").unwrap().as_str().unwrap(), "outer-span");
    }
}
