//! Benchmark harness (substrate S6; no criterion in this environment).
//!
//! Every `benches/*.rs` binary (`harness = false`) uses this: warmup +
//! measured iterations with mean/p50/p95, table rendering that mirrors the
//! paper's figures as text series, and JSON result emission so
//! EXPERIMENTS.md numbers are regenerable byte-for-byte.

use std::time::Instant;

use crate::util::json::Value;
use crate::util::stats::Samples;

/// Time a closure `iters` times after `warmup` unmeasured runs.
pub fn time_fn<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut s = Samples::new();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// One row of a result table.
#[derive(Debug, Clone)]
pub struct Row {
    pub cells: Vec<(String, Value)>,
}

impl Row {
    pub fn new() -> Self {
        Row { cells: Vec::new() }
    }

    pub fn push(mut self, key: &str, v: Value) -> Self {
        self.cells.push((key.to_string(), v));
        self
    }

    pub fn num(self, key: &str, v: f64) -> Self {
        self.push(key, Value::Num(v))
    }

    pub fn str(self, key: &str, v: &str) -> Self {
        self.push(key, Value::str(v))
    }
}

impl Default for Row {
    fn default() -> Self {
        Self::new()
    }
}

/// A named result table; renders as aligned text and as JSON.
pub struct Table {
    pub title: String,
    pub rows: Vec<Row>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), rows: Vec::new() }
    }

    pub fn add(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Render as an aligned text table (the "figure as series" output).
    pub fn render(&self) -> String {
        let mut out = format!("== {} ==\n", self.title);
        if self.rows.is_empty() {
            out.push_str("(no rows)\n");
            return out;
        }
        let headers: Vec<String> = self.rows[0].cells.iter().map(|(k, _)| k.clone()).collect();
        let fmt_cell = |v: &Value| match v {
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e12 {
                    format!("{}", *n as i64)
                } else {
                    format!("{n:.4}")
                }
            }
            Value::Str(s) => s.clone(),
            other => other.encode(),
        };
        let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
        let mut cells: Vec<Vec<String>> = Vec::new();
        for row in &self.rows {
            let mut line = Vec::new();
            for (i, (_, v)) in row.cells.iter().enumerate() {
                let s = fmt_cell(v);
                if i < widths.len() {
                    widths[i] = widths[i].max(s.len());
                }
                line.push(s);
            }
            cells.push(line);
        }
        let header_line: Vec<String> = headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        out.push_str(&header_line.join("  "));
        out.push('\n');
        for line in cells {
            let fmt: Vec<String> = line
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect();
            out.push_str(&fmt.join("  "));
            out.push('\n');
        }
        out
    }

    /// JSON form: `{"title": ..., "rows": [{...}]}`.
    pub fn to_json(&self) -> Value {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|r| Value::Obj(r.cells.iter().map(|(k, v)| (k.clone(), v.clone())).collect()))
            .collect();
        Value::obj(vec![("title", Value::str(&self.title)), ("rows", Value::Arr(rows))])
    }
}

/// Write a set of tables to `target/bench-results/<name>.json` and print them.
pub fn emit(name: &str, tables: &[Table]) {
    for t in tables {
        println!("{}", t.render());
    }
    let dir = std::path::Path::new("target/bench-results");
    let _ = std::fs::create_dir_all(dir);
    let v = Value::Arr(tables.iter().map(|t| t.to_json()).collect());
    let path = dir.join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, v.encode()) {
        eprintln!("warn: could not write {}: {e}", path.display());
    } else {
        println!("[bench] wrote {}", path.display());
    }
}

/// Write a flat machine-readable summary to `BENCH_<name>.json` in the
/// current directory. One file per bench, numeric fields only — the
/// perf-trajectory artifact CI runs can diff across commits (the full
/// tables stay in `target/bench-results/`).
pub fn emit_summary(name: &str, fields: &[(&str, f64)]) {
    let v = Value::obj(fields.iter().map(|&(k, x)| (k, Value::num(x))).collect());
    let path = format!("BENCH_{name}.json");
    if let Err(e) = std::fs::write(&path, v.encode()) {
        eprintln!("warn: could not write {path}: {e}");
    } else {
        println!("[bench] wrote {path}");
    }
}

/// ASCII heatmap rendering (Fig. 11). `grid[r][c]` in [0,1].
pub fn render_heatmap(grid: &[Vec<f32>], row_label: &str, col_label: &str) -> String {
    const SHADES: &[char] = &[' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut out = format!("rows: {row_label}, cols: {col_label}\n");
    for row in grid {
        for &v in row {
            let idx = ((v.clamp(0.0, 1.0)) * (SHADES.len() - 1) as f32).round() as usize;
            out.push(SHADES[idx]);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts() {
        let s = time_fn(2, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo");
        t.add(Row::new().str("algo", "mpic-32").num("ttft_ms", 12.5));
        t.add(Row::new().str("algo", "prefix").num("ttft_ms", 120.0));
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("mpic-32"));
        assert!(s.contains("12.5"));
    }

    #[test]
    fn json_roundtrip() {
        let mut t = Table::new("x");
        t.add(Row::new().num("a", 1.0));
        let v = t.to_json();
        assert_eq!(v.get("title").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn heatmap_shades() {
        let s = render_heatmap(&[vec![0.0, 1.0]], "r", "c");
        assert!(s.lines().nth(1).unwrap().contains('@'));
    }
}
