//! Fixed-size worker pool (substrate S3; no tokio in this environment).
//!
//! Drives the parallel transfer engine (paper Fig. 6: compute and load KV
//! caches concurrently), the TCP server's connection handlers, and the
//! workload drivers. Jobs are `FnOnce` closures; `scope`-style joins are
//! expressed with [`WaitGroup`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;

use crate::util::sync::{LockRank, OrderedCondvar, OrderedMutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Process-wide pool id source: worker names embed their pool's id so
/// [`ThreadPool::is_own_worker`] can tell pools apart.
static NEXT_POOL_ID: AtomicU64 = AtomicU64::new(0);

/// A fixed pool of worker threads consuming a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    /// Thread-name prefix shared by exactly this pool's workers.
    name_prefix: String,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let name_prefix =
            format!("mpic-worker-{}-", NEXT_POOL_ID.fetch_add(1, Ordering::Relaxed));
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(OrderedMutex::new(LockRank::Pool, rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("{name_prefix}{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().recv() };
                        match job {
                            Ok(job) => {
                                // Worker survives panicking jobs; the panic
                                // surfaces at the submitter's join point.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, name_prefix }
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Whether the current thread is one of this process's pool workers
    /// (any pool).
    pub fn on_worker_thread() -> bool {
        std::thread::current().name().is_some_and(|n| n.starts_with("mpic-worker-"))
    }

    /// Whether the current thread is a worker of *this* pool. Code that
    /// *blocks* on this pool's results (e.g. [`ThreadPool::map`]) must not
    /// do so from one of its own workers — with every worker blocked, the
    /// jobs they wait on would sit in the queue forever. Blocking on a
    /// *different* pool is fine as long as that pool's jobs never block
    /// back on this one (the chunked KV codec relies on exactly this to
    /// fan out from transfer-pool workers onto the dedicated codec pool).
    pub fn is_own_worker(&self) -> bool {
        std::thread::current().name().is_some_and(|n| n.starts_with(&self.name_prefix))
    }

    /// Submit a fire-and-forget job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("workers alive");
    }

    /// Parallel map: applies `f` to each item, preserving order.
    ///
    /// `T` and `R` cross thread boundaries, so they must be `Send`; `f` is
    /// shared. Blocks until all results are in.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<OrderedMutex<Vec<Option<R>>>> =
            Arc::new(OrderedMutex::with_index(LockRank::Pool, 1, (0..n).map(|_| None).collect()));
        let wg = WaitGroup::new(n);
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let wg = wg.clone();
            self.submit(move || {
                let r = f(item);
                results.lock()[i] = Some(r);
                wg.done();
            });
        }
        wg.wait();
        // Workers may still hold their Arc clones for an instant after
        // signalling the wait group; take the results under the lock
        // instead of unwrapping the Arc.
        let mut guard = results.lock();
        guard
            .iter_mut()
            .map(|r| r.take().expect("job panicked before producing a result"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Counting completion latch.
#[derive(Clone)]
pub struct WaitGroup {
    inner: Arc<(OrderedMutex<usize>, OrderedCondvar)>,
}

impl WaitGroup {
    pub fn new(count: usize) -> Self {
        WaitGroup {
            inner: Arc::new((
                OrderedMutex::with_index(LockRank::Pool, 2, count),
                OrderedCondvar::new(),
            )),
        }
    }

    pub fn done(&self) {
        let (lock, cv) = &*self.inner;
        let mut n = lock.lock();
        *n = n.saturating_sub(1);
        if *n == 0 {
            cv.notify_all();
        }
    }

    pub fn wait(&self) {
        let (lock, cv) = &*self.inner;
        let mut n = lock.lock();
        while *n > 0 {
            n = cv.wait(n);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(100);
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let wg = wg.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                wg.done();
            });
        }
        wg.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..200).collect::<Vec<i64>>(), |x| x * 2);
        assert_eq!(out, (0..200).map(|x| x * 2).collect::<Vec<i64>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(1);
        let wg = WaitGroup::new(1);
        {
            let wg = wg.clone();
            pool.submit(move || {
                let _guard = DoneOnDrop(wg);
                // resume_unwind skips the global panic hook so libtest does
                // not attribute this *intentional* panic to a random test.
                std::panic::resume_unwind(Box::new("boom"));
            });
        }
        wg.wait();
        // Pool still functional afterwards.
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);

        struct DoneOnDrop(WaitGroup);
        impl Drop for DoneOnDrop {
            fn drop(&mut self) {
                self.0.done();
            }
        }
    }

    #[test]
    fn worker_thread_detection() {
        assert!(!ThreadPool::on_worker_thread(), "test thread is not a worker");
        let pool = ThreadPool::new(2);
        let on_worker = pool.map(vec![(), ()], |_| ThreadPool::on_worker_thread());
        assert_eq!(on_worker, vec![true, true]);
    }

    #[test]
    fn own_worker_distinguishes_pools() {
        let a = Arc::new(ThreadPool::new(2));
        let b = Arc::new(ThreadPool::new(2));
        assert!(!a.is_own_worker());
        // From an `a` worker: own pool yes, other pool no — which is what
        // makes cross-pool blocking (codec fan-out) safe.
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        let got = a.map(vec![()], move |_| (a2.is_own_worker(), b2.is_own_worker()));
        assert_eq!(got, vec![(true, false)]);
        // And an `a` worker can block on `b` without deadlock.
        let b3 = Arc::clone(&b);
        let sums = a.map(vec![1i64, 2], move |x| {
            b3.map(vec![x, x], |y| y * 10).iter().sum::<i64>()
        });
        assert_eq!(sums, vec![20, 40]);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang, must run queued jobs
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
