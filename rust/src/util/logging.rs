//! Stderr logger implementing the `log` facade (substrate S5).

use std::io::Write;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

struct StderrLogger {
    start: Instant,
    level: LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: OnceCell<StderrLogger> = OnceCell::new();

/// Install the logger. Level comes from `MPIC_LOG` (error|warn|info|debug|trace),
/// defaulting to `info`. Safe to call multiple times.
pub fn init() {
    init_with_level(None)
}

pub fn init_with_level(level: Option<LevelFilter>) {
    let level = level.unwrap_or_else(|| {
        match std::env::var("MPIC_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => LevelFilter::Error,
            "warn" => LevelFilter::Warn,
            "debug" => LevelFilter::Debug,
            "trace" => LevelFilter::Trace,
            "off" => LevelFilter::Off,
            _ => LevelFilter::Info,
        }
    });
    let logger = LOGGER.get_or_init(|| StderrLogger { start: Instant::now(), level });
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
