//! Ranked locks: deadlock prevention by construction, with a single
//! centralized poison policy.
//!
//! # The global lock order (source of truth)
//!
//! Every lock in the serving system carries a static [`LockRank`]. A
//! thread may only acquire a lock whose `(rank, index)` is **strictly
//! greater** than every lock it already holds:
//!
//! ```text
//! Router < Pipeline < Scheduler < Transfer < StoreShard < LeaseDir
//!        < Pool < Metrics < Trace
//! ```
//!
//! Same-rank acquisitions must follow ascending *index* order (store
//! shards by shard index, transport caches `negative(0) < dead_until(1)`,
//! and so on). Concretely, the ranked locks in the tree today:
//!
//! | rank       | index | lock                                            |
//! |------------|-------|-------------------------------------------------|
//! | `Router`   | 0     | `cluster::router` worker-occupancy vector       |
//! | `Pipeline` | 0     | `server::pipeline` upload-job table             |
//! | `Scheduler`| 0     | `cache::chunk_lib` chunk registry               |
//! | `Scheduler`| 1     | `cache::static_lib` per-user file registry      |
//! | `Scheduler`| 2     | `cache::dynamic_lib` reference list             |
//! | `Scheduler`| 3     | `cache::dynamic_lib` generation counter         |
//! | `Transfer` | 0     | `kv::transfer` fetch result slots               |
//! | `Transfer` | 1     | `kv::transfer` stream state (`FetchStream`)     |
//! | `Transfer` | 2     | `cluster::transport` negative-probe cache       |
//! | `Transfer` | 3     | `cluster::transport` dead-peer cooldown map     |
//! | `StoreShard`| i    | `kv::store` shard *i* (ascending by shard index)|
//! | `LeaseDir` | 0     | `kv::store` lease-id directory                  |
//! | `Pool`     | 0     | `util::threadpool` job receiver                 |
//! | `Pool`     | 1     | `util::threadpool` `map()` result slots         |
//! | `Pool`     | 2     | `util::threadpool` `WaitGroup` counter          |
//! | `Metrics`  | 0     | `coordinator::metrics` inner aggregates         |
//! | `Trace`    | 0     | `util::trace` flight-recorder ring              |
//!
//! Debug builds keep a thread-local stack of held ranks and panic with
//! **both** acquisition sites on any out-of-order acquire; release
//! builds compile the checks away entirely — [`OrderedMutex::lock`] is
//! a plain `std::sync::Mutex::lock` with poison recovery.
//!
//! # Poison policy
//!
//! All poison handling lives here, nowhere else:
//!
//! * [`OrderedMutex::lock`] — **recover and log**: a poisoned lock is
//!   taken over (`into_inner` semantics) and a `warn` names the lock.
//!   This is the policy for metrics, tracing, routing and other
//!   advisory state, where losing a panicking writer's partial update
//!   is strictly better than cascading the panic into every reader.
//! * [`OrderedMutex::lock_checked`] — **propagate typed errors**: a
//!   poisoned lock surfaces as [`PoisonedLock`], a `std::error::Error`
//!   the store/transfer `Result` paths can bubble to their callers.
//!
//! # Race shaking
//!
//! With `MPIC_SYNC_YIELD_SEED` set (or [`set_yield_seed`] called), every
//! debug-build acquisition may insert `thread::yield_now()` calls driven
//! by a seeded per-thread RNG. This widens interleaving windows so the
//! concurrency stress tests explore schedules a quiet machine would
//! never produce, deterministically per seed.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

#[cfg(debug_assertions)]
use std::cell::RefCell;
#[cfg(debug_assertions)]
use std::panic::Location;

// ---------------------------------------------------------------------------
// Ranks
// ---------------------------------------------------------------------------

/// The global acquisition order. See the module doc — that table is the
/// source of truth; add new ranks only by extending it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum LockRank {
    Router = 0,
    Pipeline = 1,
    Scheduler = 2,
    Transfer = 3,
    StoreShard = 4,
    LeaseDir = 5,
    Pool = 6,
    Metrics = 7,
    Trace = 8,
}

impl LockRank {
    pub fn name(self) -> &'static str {
        match self {
            LockRank::Router => "Router",
            LockRank::Pipeline => "Pipeline",
            LockRank::Scheduler => "Scheduler",
            LockRank::Transfer => "Transfer",
            LockRank::StoreShard => "StoreShard",
            LockRank::LeaseDir => "LeaseDir",
            LockRank::Pool => "Pool",
            LockRank::Metrics => "Metrics",
            LockRank::Trace => "Trace",
        }
    }
}

/// Typed poison error for the `lock_checked` policy: the thread that
/// held this lock panicked, so its protected state may be mid-update.
#[derive(Debug, Clone, Copy)]
pub struct PoisonedLock {
    pub rank: LockRank,
    pub index: u32,
}

impl std::fmt::Display for PoisonedLock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let (rank, index) = (self.rank.name(), self.index);
        write!(f, "lock {rank}#{index} is poisoned (a holder panicked mid-update)")
    }
}

impl std::error::Error for PoisonedLock {}

// ---------------------------------------------------------------------------
// Debug-build rank checking
// ---------------------------------------------------------------------------

#[cfg(debug_assertions)]
#[derive(Clone, Copy)]
struct Held {
    rank: u8,
    index: u32,
    site: &'static Location<'static>,
}

#[cfg(debug_assertions)]
thread_local! {
    /// Locks this thread currently holds, in acquisition order. The
    /// ordering invariant makes this sorted by `(rank, index)`, so the
    /// last element is always the maximum held.
    static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
}

/// Check `(rank, index)` against the held stack and push it. Panics with
/// both acquisition sites on an out-of-order acquire.
#[cfg(debug_assertions)]
fn push_held(rank: LockRank, index: u32, site: &'static Location<'static>) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(top) = held.last() {
            if (rank as u8, index) <= (top.rank, top.index) {
                // Release the borrow before panicking: the panic may be
                // caught (tests) and the thread must stay usable.
                let prev = *top;
                drop(held);
                panic!(
                    "lock-rank violation: acquiring {}#{} at {} while holding {}#{} acquired at {} \
                     (global order: Router < Pipeline < Scheduler < Transfer < StoreShard < \
                     LeaseDir < Pool < Metrics < Trace; same rank must ascend by index)",
                    rank.name(),
                    index,
                    site,
                    rank_name(prev.rank),
                    prev.index,
                    prev.site,
                );
            }
        }
        held.push(Held { rank: rank as u8, index, site });
    });
}

#[cfg(debug_assertions)]
fn rank_name(r: u8) -> &'static str {
    match r {
        0 => "Router",
        1 => "Pipeline",
        2 => "Scheduler",
        3 => "Transfer",
        4 => "StoreShard",
        5 => "LeaseDir",
        6 => "Pool",
        7 => "Metrics",
        _ => "Trace",
    }
}

/// Pop one held entry. Releases are not necessarily LIFO (a caller may
/// drop an earlier guard while keeping a later one), so remove by
/// identity, searching from the end.
#[cfg(debug_assertions)]
fn pop_held(rank: LockRank, index: u32) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|x| x.rank == rank as u8 && x.index == index) {
            held.remove(pos);
        }
    });
}

// ---------------------------------------------------------------------------
// Yield injection (debug builds only)
// ---------------------------------------------------------------------------

#[cfg(debug_assertions)]
mod shake {
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// `u64::MAX` = uninitialised (read env on first use); `u64::MAX - 1`
    /// = explicitly disabled; anything else = the active seed.
    const UNSET: u64 = u64::MAX;
    const OFF: u64 = u64::MAX - 1;
    static SEED: AtomicU64 = AtomicU64::new(UNSET);
    static THREAD_SALT: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);

    fn global_seed() -> u64 {
        let s = SEED.load(Ordering::Relaxed);
        if s != UNSET {
            return s;
        }
        let from_env = std::env::var("MPIC_SYNC_YIELD_SEED")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(|v| if v >= OFF { OFF - 1 } else { v })
            .unwrap_or(OFF);
        // First writer wins; racing initialisers agree on the env value.
        let _ = SEED.compare_exchange(UNSET, from_env, Ordering::Relaxed, Ordering::Relaxed);
        SEED.load(Ordering::Relaxed)
    }

    /// Programmatic override of `MPIC_SYNC_YIELD_SEED` — tests share one
    /// process, so env latching alone can't turn the mode on per-test.
    pub fn set_yield_seed(seed: Option<u64>) {
        let v = seed.map(|v| if v >= OFF { OFF - 1 } else { v }).unwrap_or(OFF);
        SEED.store(v, Ordering::Relaxed);
    }

    thread_local! {
        static RNG: Cell<u64> = const { Cell::new(0) };
    }

    /// Maybe `yield_now()` before an acquisition: ~1 in 4 acquires yield
    /// once, ~1 in 16 yield twice. Deterministic per (seed, thread spawn
    /// order, acquisition sequence).
    pub fn maybe_yield() {
        let seed = global_seed();
        if seed == OFF {
            return;
        }
        RNG.with(|r| {
            let mut x = r.get();
            if x == 0 {
                // Derive a per-thread stream from the global seed and a
                // process-wide spawn counter (no wall clock: schedules
                // must replay from the seed alone).
                let salt = THREAD_SALT.fetch_add(0x2545_F491_4F6C_DD1D, Ordering::Relaxed);
                x = (seed ^ salt) | 1;
            }
            // xorshift64*
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            r.set(x);
            let draw = x.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 60;
            if draw < 4 {
                std::thread::yield_now();
                if draw == 0 {
                    std::thread::yield_now();
                }
            }
        });
    }
}

/// Enable (`Some(seed)`) or disable (`None`) randomized yields on lock
/// acquisition in debug builds. No-op in release builds.
#[cfg(debug_assertions)]
pub fn set_yield_seed(seed: Option<u64>) {
    shake::set_yield_seed(seed);
}

/// Release builds: yield injection compiles away.
#[cfg(not(debug_assertions))]
pub fn set_yield_seed(_seed: Option<u64>) {}

#[cfg(debug_assertions)]
#[inline]
fn on_acquire(rank: LockRank, index: u32, site: &'static Location<'static>) {
    shake::maybe_yield();
    push_held(rank, index, site);
}

// ---------------------------------------------------------------------------
// OrderedMutex
// ---------------------------------------------------------------------------

/// A `std::sync::Mutex` carrying a static `(rank, index)` position in the
/// global lock order. See the module doc for the order and the poison
/// policy. Zero overhead over `std::sync::Mutex` in release builds.
pub struct OrderedMutex<T: ?Sized> {
    rank: LockRank,
    index: u32,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// A ranked mutex at index 0 of its rank.
    pub const fn new(rank: LockRank, value: T) -> OrderedMutex<T> {
        OrderedMutex { rank, index: 0, inner: Mutex::new(value) }
    }

    /// A ranked mutex at an explicit same-rank index (store shards use
    /// their shard index; sibling locks in one module count up from 0).
    pub const fn with_index(rank: LockRank, index: u32, value: T) -> OrderedMutex<T> {
        OrderedMutex { rank, index, inner: Mutex::new(value) }
    }

    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(|p| p.into_inner())
    }
}

impl<T: ?Sized> OrderedMutex<T> {
    /// Acquire, recovering from poison (recover-and-log policy). Panics
    /// in debug builds on a lock-order violation.
    #[track_caller]
    pub fn lock(&self) -> OrderedMutexGuard<'_, T> {
        #[cfg(debug_assertions)]
        on_acquire(self.rank, self.index, Location::caller());
        let guard = self.inner.lock().unwrap_or_else(|p| {
            log::warn!(
                "recovering poisoned lock {}#{} (a holder panicked; state may be mid-update)",
                self.rank.name(),
                self.index
            );
            p.into_inner()
        });
        OrderedMutexGuard { lock: self, guard: Some(guard) }
    }

    /// Acquire, surfacing poison as a typed error (propagate policy for
    /// the store/transfer `Result` paths).
    #[track_caller]
    pub fn lock_checked(&self) -> Result<OrderedMutexGuard<'_, T>, PoisonedLock> {
        #[cfg(debug_assertions)]
        on_acquire(self.rank, self.index, Location::caller());
        match self.inner.lock() {
            Ok(guard) => Ok(OrderedMutexGuard { lock: self, guard: Some(guard) }),
            Err(_) => {
                #[cfg(debug_assertions)]
                pop_held(self.rank, self.index);
                Err(PoisonedLock { rank: self.rank, index: self.index })
            }
        }
    }

    /// Non-blocking acquire; `None` when the lock is currently held
    /// elsewhere. Poison recovers (an uncontended poisoned lock is still
    /// an acquisition). Rank-checked like `lock` — a try-acquire that
    /// would deadlock under contention is still an ordering bug.
    #[track_caller]
    pub fn try_lock(&self) -> Option<OrderedMutexGuard<'_, T>> {
        #[cfg(debug_assertions)]
        on_acquire(self.rank, self.index, Location::caller());
        match self.inner.try_lock() {
            Ok(guard) => Some(OrderedMutexGuard { lock: self, guard: Some(guard) }),
            Err(std::sync::TryLockError::Poisoned(p)) => {
                log::warn!(
                    "recovering poisoned lock {}#{} (a holder panicked; state may be mid-update)",
                    self.rank.name(),
                    self.index
                );
                Some(OrderedMutexGuard { lock: self, guard: Some(p.into_inner()) })
            }
            Err(std::sync::TryLockError::WouldBlock) => {
                #[cfg(debug_assertions)]
                pop_held(self.rank, self.index);
                None
            }
        }
    }

    /// Non-blocking acquire with the propagate-poison policy: `None`
    /// when held elsewhere, `Some(Err)` when poisoned.
    #[track_caller]
    pub fn try_lock_checked(&self) -> Option<Result<OrderedMutexGuard<'_, T>, PoisonedLock>> {
        #[cfg(debug_assertions)]
        on_acquire(self.rank, self.index, Location::caller());
        match self.inner.try_lock() {
            Ok(guard) => Some(Ok(OrderedMutexGuard { lock: self, guard: Some(guard) })),
            Err(std::sync::TryLockError::Poisoned(_)) => {
                #[cfg(debug_assertions)]
                pop_held(self.rank, self.index);
                Some(Err(PoisonedLock { rank: self.rank, index: self.index }))
            }
            Err(std::sync::TryLockError::WouldBlock) => {
                #[cfg(debug_assertions)]
                pop_held(self.rank, self.index);
                None
            }
        }
    }
}

impl<T: ?Sized + std::fmt::Debug> std::fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrderedMutex")
            .field("rank", &self.rank.name())
            .field("index", &self.index)
            .field("inner", &self.inner)
            .finish()
    }
}

/// Guard for [`OrderedMutex`]; pops the thread-local held stack on drop
/// in debug builds.
pub struct OrderedMutexGuard<'a, T: ?Sized> {
    lock: &'a OrderedMutex<T>,
    /// `Some` except transiently inside a condvar wait.
    guard: Option<MutexGuard<'a, T>>,
}

impl<T: ?Sized> std::ops::Deref for OrderedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present outside condvar wait")
    }
}

impl<T: ?Sized> std::ops::DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard present outside condvar wait")
    }
}

impl<T: ?Sized> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        pop_held(self.lock.rank, self.lock.index);
        #[cfg(not(debug_assertions))]
        let _ = &self.lock;
    }
}

// ---------------------------------------------------------------------------
// OrderedCondvar
// ---------------------------------------------------------------------------

/// A condvar usable with [`OrderedMutexGuard`]. While a thread waits,
/// the lock is released by the OS but the held-stack entry is retained —
/// the thread is blocked, so it cannot acquire anything else, and on
/// wakeup it holds the lock again with the same ordering position.
pub struct OrderedCondvar {
    cv: Condvar,
}

impl Default for OrderedCondvar {
    fn default() -> Self {
        Self::new()
    }
}

impl OrderedCondvar {
    pub const fn new() -> OrderedCondvar {
        OrderedCondvar { cv: Condvar::new() }
    }

    pub fn wait<'a, T: ?Sized>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
    ) -> OrderedMutexGuard<'a, T> {
        let lock = guard.lock;
        let inner = guard.guard.take().expect("wait on a live guard");
        let inner = self.cv.wait(inner).unwrap_or_else(|p| {
            log::warn!(
                "recovering poisoned lock {}#{} on condvar wakeup",
                lock.rank.name(),
                lock.index
            );
            p.into_inner()
        });
        guard.guard = Some(inner);
        guard
    }

    pub fn wait_timeout<'a, T: ?Sized>(
        &self,
        mut guard: OrderedMutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> (OrderedMutexGuard<'a, T>, bool) {
        let lock = guard.lock;
        let inner = guard.guard.take().expect("wait on a live guard");
        let (inner, timeout) = match self.cv.wait_timeout(inner, dur) {
            Ok((g, t)) => (g, t.timed_out()),
            Err(p) => {
                log::warn!(
                    "recovering poisoned lock {}#{} on condvar wakeup",
                    lock.rank.name(),
                    lock.index
                );
                let (g, t) = p.into_inner();
                (g, t.timed_out())
            }
        };
        guard.guard = Some(inner);
        (guard, timeout)
    }

    pub fn notify_one(&self) {
        self.cv.notify_one();
    }

    pub fn notify_all(&self) {
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// OrderedRwLock
// ---------------------------------------------------------------------------

/// A `std::sync::RwLock` in the same global order. Both read and write
/// acquisitions are rank-checked (a reader blocking behind a writer
/// deadlocks exactly like a mutex would).
pub struct OrderedRwLock<T: ?Sized> {
    rank: LockRank,
    index: u32,
    inner: RwLock<T>,
}

impl<T> OrderedRwLock<T> {
    pub const fn new(rank: LockRank, value: T) -> OrderedRwLock<T> {
        OrderedRwLock { rank, index: 0, inner: RwLock::new(value) }
    }

    pub const fn with_index(rank: LockRank, index: u32, value: T) -> OrderedRwLock<T> {
        OrderedRwLock { rank, index, inner: RwLock::new(value) }
    }
}

impl<T: ?Sized> OrderedRwLock<T> {
    #[track_caller]
    pub fn read(&self) -> OrderedReadGuard<'_, T> {
        #[cfg(debug_assertions)]
        on_acquire(self.rank, self.index, Location::caller());
        let guard = self.inner.read().unwrap_or_else(|p| {
            log::warn!("recovering poisoned rwlock {}#{}", self.rank.name(), self.index);
            p.into_inner()
        });
        OrderedReadGuard { lock: self, guard }
    }

    #[track_caller]
    pub fn write(&self) -> OrderedWriteGuard<'_, T> {
        #[cfg(debug_assertions)]
        on_acquire(self.rank, self.index, Location::caller());
        let guard = self.inner.write().unwrap_or_else(|p| {
            log::warn!("recovering poisoned rwlock {}#{}", self.rank.name(), self.index);
            p.into_inner()
        });
        OrderedWriteGuard { lock: self, guard }
    }
}

pub struct OrderedReadGuard<'a, T: ?Sized> {
    lock: &'a OrderedRwLock<T>,
    guard: RwLockReadGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for OrderedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T: ?Sized> Drop for OrderedReadGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        pop_held(self.lock.rank, self.lock.index);
        #[cfg(not(debug_assertions))]
        let _ = &self.lock;
    }
}

pub struct OrderedWriteGuard<'a, T: ?Sized> {
    lock: &'a OrderedRwLock<T>,
    guard: RwLockWriteGuard<'a, T>,
}

impl<T: ?Sized> std::ops::Deref for OrderedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.guard
    }
}

impl<T: ?Sized> std::ops::DerefMut for OrderedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.guard
    }
}

impl<T: ?Sized> Drop for OrderedWriteGuard<'_, T> {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        pop_held(self.lock.rank, self.lock.index);
        #[cfg(not(debug_assertions))]
        let _ = &self.lock;
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn ascending_acquisition_is_legal() {
        let a = OrderedMutex::new(LockRank::Router, 1u32);
        let b = OrderedMutex::new(LockRank::Metrics, 2u32);
        let ga = a.lock();
        let gb = b.lock();
        assert_eq!(*ga + *gb, 3);
    }

    #[test]
    fn same_rank_ascending_index_is_legal() {
        let s0 = OrderedMutex::with_index(LockRank::StoreShard, 0, ());
        let s3 = OrderedMutex::with_index(LockRank::StoreShard, 3, ());
        let _g0 = s0.lock();
        let _g3 = s3.lock();
    }

    #[test]
    fn non_lifo_release_keeps_the_stack_consistent() {
        let a = OrderedMutex::new(LockRank::Pipeline, ());
        let b = OrderedMutex::new(LockRank::Pool, ());
        let c = OrderedMutex::new(LockRank::Trace, ());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga); // release the *earlier* lock first
        let _gc = c.lock(); // still legal: max held is Pool
        drop(gb);
        // And Pipeline is re-acquirable now that Pool/Trace context is
        // irrelevant to it being the new max.
        drop(_gc);
        let _ga2 = a.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn out_of_order_acquire_panics_with_both_sites() {
        let err = std::thread::spawn(|| {
            let hi = OrderedMutex::new(LockRank::Metrics, ());
            let lo = OrderedMutex::new(LockRank::StoreShard, ());
            let _g = hi.lock();
            let _g2 = lo.lock(); // violation: StoreShard after Metrics
        })
        .join()
        .unwrap_err();
        let msg = err.downcast_ref::<String>().expect("panic payload");
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
        assert!(msg.contains("StoreShard#0"), "names the acquiring lock: {msg}");
        assert!(msg.contains("Metrics#0"), "names the held lock: {msg}");
        // Both acquisition sites are file:line in this file.
        assert_eq!(msg.matches("sync.rs").count(), 2, "both sites cited: {msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn same_rank_descending_index_panics() {
        let err = std::thread::spawn(|| {
            let s1 = OrderedMutex::with_index(LockRank::StoreShard, 1, ());
            let s0 = OrderedMutex::with_index(LockRank::StoreShard, 0, ());
            let _g1 = s1.lock();
            let _g0 = s0.lock();
        })
        .join()
        .unwrap_err();
        let msg = err.downcast_ref::<String>().expect("panic payload");
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn violation_panic_leaves_the_thread_usable() {
        // A caught rank panic must not wedge the held stack: the lock we
        // failed to acquire was never pushed, and the one we held drops.
        let hi = OrderedMutex::new(LockRank::Trace, ());
        let lo = OrderedMutex::new(LockRank::Router, ());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = hi.lock();
            let _g2 = lo.lock();
        }));
        assert!(r.is_err());
        // Fresh ascending acquisitions still work on this thread.
        let _a = lo.lock();
        let _b = hi.lock();
    }

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(OrderedMutex::new(LockRank::Metrics, 7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        // Recover-and-log policy: the value is still reachable.
        assert_eq!(*m.lock(), 7);
    }

    #[test]
    fn lock_checked_propagates_poison_as_typed_error() {
        let m = Arc::new(OrderedMutex::with_index(LockRank::StoreShard, 2, 0u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        let err = m.lock_checked().expect_err("poison must surface");
        assert_eq!(err.rank, LockRank::StoreShard);
        assert_eq!(err.index, 2);
        let msg = format!("{err}");
        assert!(msg.contains("StoreShard#2"), "typed error names the lock: {msg}");
        // An anyhow context chain accepts it (the store's error idiom).
        let any: anyhow::Error = err.into();
        assert!(format!("{any:#}").contains("poisoned"));
    }

    #[test]
    fn try_lock_contended_returns_none_and_pops_stack() {
        let m = Arc::new(OrderedMutex::new(LockRank::StoreShard, ()));
        let g = m.lock();
        let m2 = Arc::clone(&m);
        std::thread::spawn(move || {
            assert!(m2.try_lock().is_none());
            // The failed try above must not leave a phantom held entry:
            // acquiring a *lower* rank now must still be legal.
            let lo = OrderedMutex::new(LockRank::Router, ());
            let _g = lo.lock();
        })
        .join()
        .unwrap();
        drop(g);
        assert!(m.try_lock().is_some());
    }

    #[test]
    fn condvar_roundtrip_preserves_ordering_state() {
        let m = Arc::new(OrderedMutex::new(LockRank::Pool, false));
        let cv = Arc::new(OrderedCondvar::new());
        let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
        let t = std::thread::spawn(move || {
            let mut g = m2.lock();
            while !*g {
                g = cv2.wait(g);
            }
            // Post-wait the guard still participates in ordering: a
            // higher-rank acquire is legal.
            let hi = OrderedMutex::new(LockRank::Trace, ());
            let _g2 = hi.lock();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        *m.lock() = true;
        cv.notify_all();
        t.join().unwrap();
    }

    #[test]
    fn condvar_wait_timeout_reports_timeout() {
        let m = OrderedMutex::new(LockRank::Pool, ());
        let cv = OrderedCondvar::new();
        let g = m.lock();
        let (_g, timed_out) = cv.wait_timeout(g, std::time::Duration::from_millis(5));
        assert!(timed_out);
    }

    #[test]
    fn rwlock_read_write_roundtrip() {
        let l = OrderedRwLock::new(LockRank::Scheduler, 1u32);
        {
            let mut w = l.write();
            *w += 1;
        }
        assert_eq!(*l.read(), 2);
        // Ascending into a mutex while holding a read guard is legal.
        let m = OrderedMutex::new(LockRank::Trace, ());
        let _r = l.read();
        let _g = m.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn yield_injection_is_harmless_and_deterministic_per_seed() {
        set_yield_seed(Some(42));
        let m = Arc::new(OrderedMutex::new(LockRank::StoreShard, 0u64));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let m = Arc::clone(&m);
            handles.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    *m.lock() += 1;
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        set_yield_seed(None);
        assert_eq!(*m.lock(), 2000);
    }
}
