//! Property-testing helper (substrate S7; no proptest in this environment).
//!
//! Deterministic seeded case generation with a simple halving shrinker: a
//! failing case is re-run with progressively simpler inputs produced by the
//! caller's `simplify` hook until it stops failing, and the minimal failing
//! seed/case is reported in the panic message.

use crate::util::rng::Rng;

/// Run `cases` random property checks. `gen` builds a case from an RNG,
/// `check` returns `Err(reason)` on violation.
pub fn check<T: std::fmt::Debug, G, C>(name: &str, cases: usize, mut gen: G, mut check: C)
where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let base = Rng::new(0x4D504943_u64 ^ crate::util::rng::fnv1a(name.as_bytes()));
    for case_idx in 0..cases {
        let mut rng = base.fork(case_idx as u64);
        let input = gen(&mut rng);
        if let Err(reason) = check(&input) {
            panic!(
                "property {name:?} failed on case {case_idx}:\n  reason: {reason}\n  input: {input:?}"
            );
        }
    }
}

/// Run `cases` checks with shrinking: on failure, `simplify` proposes
/// smaller variants (best-first); the smallest still-failing one is reported.
pub fn check_shrink<T: Clone + std::fmt::Debug, G, C, S>(
    name: &str,
    cases: usize,
    mut gen: G,
    mut test: C,
    mut simplify: S,
) where
    G: FnMut(&mut Rng) -> T,
    C: FnMut(&T) -> Result<(), String>,
    S: FnMut(&T) -> Vec<T>,
{
    let base = Rng::new(0x4D504943_u64 ^ crate::util::rng::fnv1a(name.as_bytes()));
    for case_idx in 0..cases {
        let mut rng = base.fork(case_idx as u64);
        let input = gen(&mut rng);
        if let Err(first_reason) = test(&input) {
            // Greedy shrink loop, bounded to avoid pathological cycles.
            let mut best = input.clone();
            let mut reason = first_reason;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in simplify(&best) {
                    budget -= 1;
                    if let Err(r) = test(&cand) {
                        best = cand;
                        reason = r;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property {name:?} failed on case {case_idx} (shrunk):\n  reason: {reason}\n  input: {best:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        check("add-commutes", 50, |r| (r.below(100), r.below(100)), |&(a, b)| {
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn failing_property_panics() {
        check("always-fails", 5, |r| r.below(10), |_| Err("always-fails".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinking_reduces_case() {
        // Fails for any n >= 3; shrinker should walk toward 3.
        check_shrink(
            "ge3",
            1,
            |r| 50 + r.below(50),
            |&n| if n >= 3 { Err(format!("n={n} >= 3")) } else { Ok(()) },
            |&n| if n > 0 { vec![n / 2, n - 1] } else { vec![] },
        );
    }
}
