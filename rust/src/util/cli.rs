//! Tiny CLI argument parser (substrate S4; no clap in this environment).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments,
//! with typed accessors and defaulting. Each binary declares its usage
//! string by hand (they are short).

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list. `flag_names` lists options that
    /// take no value.
    pub fn parse_from(tokens: impl IntoIterator<Item = String>, flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{body} expects a value"))?;
                    out.options.insert(body.to_string(), v);
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process command line (skipping argv[0]).
    pub fn parse(flag_names: &[&str]) -> Result<Args> {
        Self::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be an integer, got {s:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be an integer, got {s:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().with_context(|| format!("--{name} must be a number, got {s:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse_from(toks("serve --port 9000 --verbose --model=mpic-sim-a extra"), &["verbose"]).unwrap();
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert_eq!(a.get("port"), Some("9000"));
        assert_eq!(a.get("model"), Some("mpic-sim-a"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse_from(toks("--n 5 --rate 1.5"), &[]).unwrap();
        assert_eq!(a.usize_or("n", 1).unwrap(), 5);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!((a.f64_or("rate", 0.0).unwrap() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse_from(toks("--port"), &[]).is_err());
    }

    #[test]
    fn bad_type_errors() {
        let a = Args::parse_from(toks("--n five"), &[]).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }
}
