//! Minimal JSON encoder/decoder (substrate S1).
//!
//! Parses the AOT `manifest.json` contract and serialises bench results and
//! server protocol messages. Supports the full JSON grammar except for
//! `\u` surrogate pairs outside the BMP being combined (sufficient for our
//! ASCII manifests); numbers are `f64`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {}", self.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => Err(anyhow!("expected array, got {}", self.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string, got {}", self.kind())),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number, got {}", self.kind())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool, got {}", self.kind())),
        }
    }

    /// Member lookup on an object.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional member lookup.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Arr(_) => "array",
            Value::Obj(_) => "object",
        }
    }

    // ---- construction helpers -------------------------------------------

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Value {
        Value::Num(n.into())
    }

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Value>) -> Value {
        Value::Arr(items)
    }

    /// Insert (or replace) a member on an object; no-op on non-objects.
    pub fn set(&mut self, key: &str, val: Value) {
        if let Value::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    /// Serialise to a compact JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Value::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // Re-assemble UTF-8 multi-byte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let start = self.i - 1;
                        self.i = start + len;
                        if self.i > self.b.len() {
                            bail!("truncated UTF-8");
                        }
                        out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']' got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}' got {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Value::parse(s).unwrap();
            assert_eq!(Value::parse(&v.encode()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        let b = v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap();
        assert_eq!(b.as_str().unwrap(), "x\ny");
    }

    #[test]
    fn encode_escapes() {
        let v = Value::str("a\"b\\c\nd");
        assert_eq!(v.encode(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{,}").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12abc").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Value::num(42.0).encode(), "42");
        assert_eq!(Value::num(1.25).encode(), "1.25");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
        assert_eq!(Value::parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn usize_accessor_guards() {
        assert!(Value::num(-1.0).as_usize().is_err());
        assert!(Value::num(1.5).as_usize().is_err());
        assert_eq!(Value::num(7.0).as_usize().unwrap(), 7);
    }

    #[test]
    fn u64_accessor_guards() {
        assert!(Value::num(-1.0).as_u64().is_err());
        assert!(Value::str("x").as_u64().is_err());
        assert_eq!(Value::num(9.0).as_u64().unwrap(), 9);
    }

    #[test]
    fn set_inserts_and_replaces() {
        let mut v = Value::obj(vec![("a", Value::num(1.0))]);
        v.set("b", Value::Bool(true));
        v.set("a", Value::num(2.0));
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 2.0);
        assert!(v.get("b").unwrap().as_bool().unwrap());
        // No-op on non-objects.
        let mut n = Value::num(1.0);
        n.set("a", Value::Null);
        assert_eq!(n, Value::num(1.0));
    }
}
