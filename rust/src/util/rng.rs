//! Deterministic PRNG (substrate S2): SplitMix64 + helpers.
//!
//! Used by the synthetic workload generators, image patch synthesis and the
//! property-test helper. Determinism across runs/platforms is a hard
//! requirement (EXPERIMENTS.md results must be reproducible), hence a fixed
//! in-tree algorithm rather than `std`'s unspecified hasher.

/// SplitMix64: tiny, fast, passes BigCrush when used as a 64-bit stream.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent stream (stable hash-combine of the label).
    pub fn fork(&self, label: u64) -> Rng {
        Rng::new(
            self.state
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(label.wrapping_mul(0xBF58476D1CE4E5B9).wrapping_add(1)),
        )
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

/// Stable 64-bit FNV-1a hash (tokenizer, cache keys).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_distribution_sane() {
        let mut r = Rng::new(3);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_distribution_sane() {
        let mut r = Rng::new(4);
        let n = 10_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a(b"mpic"), fnv1a(b"mpic"));
        assert_ne!(fnv1a(b"mpic"), fnv1a(b"mpiC"));
    }
}
