//! Summary statistics (substrate S2): mean, stddev, percentiles, histograms.

/// Streaming-friendly sample collector with percentile queries.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Empirical CDF over a sample set: returns `(x, F(x))` pairs at each sample.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    sorted
        .iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples(xs: &[f64]) -> Samples {
        let mut s = Samples::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    #[test]
    fn mean_stddev() {
        let s = samples(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }

    #[test]
    fn ecdf_monotone() {
        let pairs = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(pairs.len(), 3);
        assert_eq!(pairs[0], (1.0, 1.0 / 3.0));
        assert_eq!(pairs[2], (3.0, 1.0));
    }
}
