//! Summary statistics (substrate S2): mean, stddev, percentiles, histograms.

/// Streaming-friendly sample collector with percentile queries.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.xs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, `p` in `[0, 100]`.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn values(&self) -> &[f64] {
        &self.xs
    }
}

/// Fixed log-bucketed histogram: constant memory however many values are
/// observed, O(buckets) percentile queries, and cumulative bucket counts in
/// the shape Prometheus histogram exposition wants.
///
/// Buckets are powers of two from `2^MIN_EXP` to `2^MAX_EXP` (≈1 µs to ≈256 s
/// for latencies in seconds) plus an overflow bucket, so a quantile estimate
/// is exact to within one bucket (a factor of 2). Exact `sum`, `count`, `min`
/// and `max` are tracked on the side; `mean()` is therefore exact.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const MIN_EXP: i32 = -20;
const MAX_EXP: i32 = 8;
const N_BOUNDS: usize = (MAX_EXP - MIN_EXP + 1) as usize;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            // One count per finite upper bound, plus the +Inf overflow.
            counts: vec![0; N_BOUNDS + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Finite bucket upper bounds (`le` labels, excluding `+Inf`).
    pub fn bounds() -> impl Iterator<Item = f64> {
        (MIN_EXP..=MAX_EXP).map(|e| (e as f64).exp2())
    }

    fn bucket_of(x: f64) -> usize {
        // Index of the first bound with x <= bound; NaN and negatives fall
        // into the first bucket rather than poisoning the structure.
        if !(x > 0.0) {
            return 0;
        }
        let e = x.log2().ceil() as i64;
        (e.clamp(MIN_EXP as i64, MAX_EXP as i64 + 1) - MIN_EXP as i64) as usize
    }

    pub fn observe(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.counts[Self::bucket_of(x)] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Per-bucket (non-cumulative) counts, one per finite bound plus the
    /// overflow bucket last.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Quantile estimate, `p` in `[0, 100]`: linear interpolation inside the
    /// bucket containing the target rank, clamped to the observed min/max.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (p / 100.0).clamp(0.0, 1.0) * self.count as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (seen + c) as f64 >= rank {
                let lower = if i == 0 { 0.0 } else { ((MIN_EXP + i as i32 - 1) as f64).exp2() };
                let upper = if i < N_BOUNDS {
                    ((MIN_EXP + i as i32) as f64).exp2()
                } else {
                    self.max
                };
                let frac = ((rank - seen as f64) / c as f64).clamp(0.0, 1.0);
                let est = lower + (upper - lower) * frac;
                return est.clamp(self.min, self.max);
            }
            seen += c;
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Fold another histogram into this one (cluster-level aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Bounded uniform sample: Vitter's Algorithm R over a deterministic
/// SplitMix64 stream. Exact mean/min/max on the side; percentile queries
/// sort at most `cap` values, so a week of pushes costs constant memory.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    xs: Vec<f64>,
    seen: u64,
    sum: f64,
    min: f64,
    max: f64,
    rng: crate::util::rng::Rng,
}

impl Reservoir {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        Reservoir {
            cap,
            xs: Vec::new(),
            seen: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            rng: crate::util::rng::Rng::new(0x6d70_6963), // "mpic"
        }
    }

    pub fn push(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.seen += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.xs.len() < self.cap {
            self.xs.push(x);
        } else {
            let j = self.rng.below(self.seen) as usize;
            if j < self.cap {
                self.xs[j] = x;
            }
        }
    }

    /// Total values pushed (not the retained sample size).
    pub fn len(&self) -> u64 {
        self.seen
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    pub fn sample_len(&self) -> usize {
        self.xs.len()
    }

    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.sum / self.seen as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.seen == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Percentile over the retained sample (exact while `seen <= cap`).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
        let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Empirical CDF over a sample set: returns `(x, F(x))` pairs at each sample.
pub fn ecdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    sorted
        .iter()
        .enumerate()
        .map(|(i, &x)| (x, (i + 1) as f64 / n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samples(xs: &[f64]) -> Samples {
        let mut s = Samples::new();
        for &x in xs {
            s.push(x);
        }
        s
    }

    #[test]
    fn mean_stddev() {
        let s = samples(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let s = samples(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.p50(), 3.0);
        assert!((s.percentile(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        let s = Samples::new();
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan());
    }

    /// Bucket boundaries are `le` (inclusive-upper): a value exactly on a
    /// power of two lands in that bound's bucket, a hair above spills into
    /// the next, and out-of-range values land in the first / overflow
    /// buckets instead of being dropped.
    #[test]
    fn histogram_bucket_boundaries() {
        let bounds: Vec<f64> = Histogram::bounds().collect();
        assert_eq!(bounds.len(), N_BOUNDS);
        assert_eq!(bounds[0], (MIN_EXP as f64).exp2());
        assert_eq!(*bounds.last().unwrap(), (MAX_EXP as f64).exp2());

        let mut h = Histogram::new();
        h.observe(1.0); // == 2^0, inclusive upper bound
        let idx_one = (0 - MIN_EXP) as usize;
        assert_eq!(h.bucket_counts()[idx_one], 1);
        h.observe(1.0000001); // just above 2^0 → next bucket
        assert_eq!(h.bucket_counts()[idx_one + 1], 1);
        h.observe(0.0); // non-positive → first bucket
        h.observe(-3.0);
        assert_eq!(h.bucket_counts()[0], 2);
        h.observe(1e12); // beyond the last bound → overflow bucket
        assert_eq!(h.bucket_counts()[N_BOUNDS], 1);
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), 1e12);
        assert_eq!(h.min(), -3.0);
    }

    /// Quantiles come back within one log2 bucket of the true value, and
    /// memory stays constant however many values are observed.
    #[test]
    fn histogram_quantiles_within_bucket_tolerance() {
        let mut h = Histogram::new();
        let n_buckets = h.bucket_counts().len();
        for i in 0..100_000u64 {
            // Uniform latencies in (0, 0.1] seconds.
            h.observe((i + 1) as f64 * 1e-6);
        }
        assert_eq!(h.bucket_counts().len(), n_buckets, "no allocation growth");
        assert!((h.mean() - 0.05).abs() < 1e-3, "mean is exact: {}", h.mean());
        for (p, truth) in [(50.0, 0.05), (95.0, 0.095), (99.0, 0.099)] {
            let est = h.percentile(p);
            assert!(
                est >= truth / 2.0 && est <= truth * 2.0,
                "p{p} estimate {est} not within a bucket of {truth}"
            );
        }
        assert_eq!(h.percentile(100.0), h.max());
        assert!(Histogram::new().p50().is_nan());
    }

    #[test]
    fn histogram_merge_sums_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.observe(0.001);
        b.observe(0.001);
        b.observe(4.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!((a.sum() - 4.002).abs() < 1e-12);
        assert_eq!(a.max(), 4.0);
        let idx = Histogram::bucket_of(0.001);
        assert_eq!(a.bucket_counts()[idx], 2);
    }

    #[test]
    fn reservoir_caps_memory_keeps_exact_aggregates() {
        let mut r = Reservoir::new(64);
        for i in 0..10_000 {
            r.push(i as f64);
        }
        assert_eq!(r.sample_len(), 64, "retained sample is capped");
        assert_eq!(r.len(), 10_000);
        assert!((r.mean() - 4999.5).abs() < 1e-9, "mean is exact");
        assert_eq!(r.min(), 0.0);
        assert_eq!(r.max(), 9999.0);
        // The uniform sample keeps the median in the right neighbourhood.
        let p50 = r.p50();
        assert!((1000.0..9000.0).contains(&p50), "p50={p50}");
        assert!(Reservoir::new(4).p50().is_nan());
    }

    #[test]
    fn ecdf_monotone() {
        let pairs = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(pairs.len(), 3);
        assert_eq!(pairs[0], (1.0, 1.0 / 3.0));
        assert_eq!(pairs[2], (3.0, 1.0));
    }
}
