//! Observability end-to-end: one trace id following a request across the
//! cluster, the flight recorder answering for it, and the Prometheus
//! endpoints rendering well-formed exposition on both worker and router.
//!
//! * A generation submitted through `mpic router` without a trace id gets
//!   one minted at the router, serves on a worker that peer-pulls its KV
//!   from another worker, and the final reply echoes the id. `debug.trace
//!   get` on the serving worker then returns ONE trace whose spans cover
//!   admission → fetch → peer_pull → prefill → decode, ordered by start
//!   offset, with the peer_pull span carrying the pulled byte count.
//! * Scraping `--metrics-addr` on the worker and on the router yields
//!   parseable exposition: TYPE-only comments, no duplicate series, the
//!   `mpic_ttft_seconds` bucket family present with +Inf == count.
//! * The slow-request log fires through the `log` facade when a finished
//!   trace exceeds the threshold (recorder-level, no artifacts needed).
//!
//! The cluster test skips when artifacts are not built (same contract as
//! `serving_e2e` / `cluster_e2e`).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use mpic::cluster::{serve_router, PeerConfig, PeerTransport, RouterConfig};
use mpic::coordinator::{Engine, EngineConfig};
use mpic::server::{serve_with, Client, ServeConfig};
use mpic::util::json::Value;
use mpic::util::trace::{Recorder, TraceId};

fn artifacts_ready() -> bool {
    let ready = std::path::Path::new("artifacts/manifest.json").exists();
    if !ready && std::env::var("MPIC_REQUIRE_ARTIFACTS").map_or(false, |v| !v.is_empty()) {
        panic!("MPIC_REQUIRE_ARTIFACTS is set but artifacts/manifest.json is missing");
    }
    ready
}

fn v(s: &str) -> Value {
    Value::parse(s).unwrap()
}

fn assert_ok(resp: &Value) {
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "expected ok: {}", resp.encode());
}

/// A free `127.0.0.1` address for a metrics endpoint: bind :0, note the
/// port, release it. The tiny reuse race is acceptable in tests.
fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let a = l.local_addr().unwrap();
    drop(l);
    a.to_string()
}

/// Spawn one worker (engine + PJRT stay on the serving thread, as in
/// `cluster_e2e`), with an optional Prometheus endpoint.
fn spawn_worker(
    tag: &'static str,
    peers: Vec<SocketAddr>,
    metrics_addr: Option<String>,
) -> (SocketAddr, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let dir = std::env::temp_dir().join(format!("mpic-obs-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut engine = Engine::new(EngineConfig {
            model: "mpic-sim-a".into(),
            store: mpic::kv::StoreConfig { disk_dir: dir, ..Default::default() },
            max_new_tokens: 4,
            ..Default::default()
        })
        .expect("engine");
        if !peers.is_empty() {
            let counters = Arc::clone(engine.metrics.cluster());
            engine.set_transport(Arc::new(PeerTransport::new(
                peers,
                PeerConfig::default(),
                counters,
            )));
        }
        let cfg = ServeConfig { metrics_addr, ..Default::default() };
        serve_with(&engine, "127.0.0.1:0", cfg, |a| {
            tx.send(a).unwrap();
        })
        .expect("serve");
    });
    (rx.recv().unwrap(), handle)
}

fn shutdown_worker(addr: SocketAddr, handle: JoinHandle<()>) {
    let mut c = Client::connect(addr).unwrap();
    let resp = c.call(&v(r#"{"v":3,"id":"bye","op":"shutdown"}"#)).unwrap();
    assert_ok(&resp);
    handle.join().unwrap();
}

/// One raw HTTP GET against a metrics endpoint, with a brief retry while
/// the endpoint thread binds. Returns the exposition body.
fn scrape(addr: &str) -> String {
    let mut last = None;
    for _ in 0..50 {
        match TcpStream::connect(addr) {
            Ok(mut s) => {
                s.write_all(b"GET /metrics HTTP/1.1\r\nHost: mpic\r\nConnection: close\r\n\r\n")
                    .unwrap();
                let mut buf = String::new();
                s.read_to_string(&mut buf).unwrap();
                let (head, body) = buf.split_once("\r\n\r\n").expect("http head/body split");
                assert!(head.starts_with("HTTP/1.1 200 OK"), "bad status: {head}");
                assert!(head.contains("text/plain; version=0.0.4"), "bad content type: {head}");
                return body.to_string();
            }
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
    panic!("metrics endpoint {addr} never came up: {last:?}");
}

/// Lint one exposition body: only TYPE comments, every sample line parses
/// as `series value`, and no series repeats.
fn lint_exposition(text: &str) {
    let mut seen = std::collections::HashSet::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if line.starts_with('#') {
            assert!(line.starts_with("# TYPE "), "only TYPE comments allowed: {line:?}");
            continue;
        }
        let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        assert!(!series.is_empty(), "empty series: {line:?}");
        assert!(value.parse::<f64>().is_ok(), "unparseable value: {line:?}");
        assert!(seen.insert(series.to_string()), "duplicate series: {series}");
    }
}

/// The value of one exact series (name + label set) in an exposition body.
fn series_value(text: &str, series: &str) -> Option<f64> {
    text.lines().find_map(|l| {
        let (s, val) = l.rsplit_once(' ')?;
        if s == series {
            val.parse().ok()
        } else {
            None
        }
    })
}

#[test]
fn one_trace_id_across_router_worker_and_peer_pull() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }

    // Worker A owns the upload; worker B peers with A and is the only
    // worker behind the router, so the routed generation must serve on B
    // and pull its KV from A.
    let (a_addr, a_join) = spawn_worker("a", vec![], None);
    let b_maddr = free_addr();
    let (b_addr, b_join) = spawn_worker("b", vec![a_addr], Some(b_maddr.clone()));

    let mut ca = Client::connect(a_addr).unwrap();
    let up = ca
        .call(&v(r#"{"v":3,"id":"u1","op":"upload","user":1,"handle":"IMAGE#obs-e2e"}"#))
        .unwrap();
    assert_ok(&up);

    let (rtx, rrx) = mpsc::channel();
    let r_maddr = free_addr();
    let mut router_cfg = RouterConfig::new(vec![b_addr]);
    router_cfg.metrics_addr = Some(r_maddr.clone());
    let router_join = std::thread::spawn(move || {
        serve_router(router_cfg, "127.0.0.1:0", |a| rtx.send(a).unwrap()).unwrap();
    });
    let router_addr = rrx.recv().unwrap();
    let mut cr = Client::connect(router_addr).unwrap();

    // ------------------------------------------------------------------
    // The traced request: no client trace id, so the router mints one.
    // ------------------------------------------------------------------
    let gen = cr
        .call(&v(
            r#"{"v":3,"id":"g1","op":"infer","user":1,"text":"describe IMAGE#obs-e2e briefly","max_new":4}"#,
        ))
        .unwrap();
    assert_ok(&gen);
    let trace = gen.get("trace").unwrap().as_str().unwrap().to_string();
    assert!(
        trace.len() == 16 && trace.chars().all(|c| c.is_ascii_hexdigit()),
        "final reply must echo the minted trace id: {}",
        gen.encode()
    );

    // ------------------------------------------------------------------
    // Flight recorder on the serving worker: one trace, every stage.
    // ------------------------------------------------------------------
    let mut cb = Client::connect(b_addr).unwrap();
    let dt = cb
        .call(&v(&format!(
            r#"{{"v":3,"id":"dt","op":"debug.trace","action":"get","trace":"{trace}"}}"#
        )))
        .unwrap();
    assert_ok(&dt);
    assert!(dt.get("done").unwrap().as_bool().unwrap(), "trace must be completed: {}", dt.encode());
    assert_eq!(dt.get("op").unwrap().as_str().unwrap(), "infer");
    let spans = dt.get("spans").unwrap().as_arr().unwrap();
    let names: Vec<&str> =
        spans.iter().map(|s| s.get("name").unwrap().as_str().unwrap()).collect();
    for need in ["admission", "fetch", "peer_pull", "prefill", "decode"] {
        assert!(names.contains(&need), "span {need:?} missing from trace: {names:?}");
    }
    let starts: Vec<u64> =
        spans.iter().map(|s| s.get("start_us").unwrap().as_u64().unwrap()).collect();
    assert!(
        starts.windows(2).all(|w| w[0] <= w[1]),
        "spans must be ordered by start offset: {starts:?}"
    );
    let pull = spans
        .iter()
        .find(|s| s.get("name").unwrap().as_str().unwrap() == "peer_pull")
        .unwrap();
    assert!(
        pull.get("bytes").unwrap().as_f64().unwrap() > 0.0,
        "peer_pull span must carry the pulled byte count: {}",
        pull.encode()
    );

    // The recorder's ring lists it too.
    let list = cb.call(&v(r#"{"v":3,"id":"dl","op":"debug.trace"}"#)).unwrap();
    assert_ok(&list);
    assert!(list.get("count").unwrap().as_f64().unwrap() >= 1.0);
    let listed = list
        .get("traces")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .any(|t| t.get("trace").unwrap().as_str().unwrap() == trace);
    assert!(listed, "completed trace must appear in the list: {}", list.encode());

    // ------------------------------------------------------------------
    // stats.cluster through the router: per-worker snapshots + aggregate.
    // ------------------------------------------------------------------
    let sc = cr.call(&v(r#"{"v":3,"id":"sc","op":"stats.cluster"}"#)).unwrap();
    assert_ok(&sc);
    let workers = sc.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(workers.len(), 1);
    assert!(workers[0].get("ok").unwrap().as_bool().unwrap(), "{}", sc.encode());
    let agg = sc.get("metrics").unwrap();
    assert!(agg.get("requests").unwrap().as_f64().unwrap() >= 1.0);
    let ttft = agg.get("histograms").unwrap().get("ttft_s").unwrap();
    assert!(ttft.get("count").unwrap().as_f64().unwrap() >= 1.0, "{}", sc.encode());

    // ------------------------------------------------------------------
    // Prometheus endpoints: worker and router both serve clean exposition.
    // ------------------------------------------------------------------
    for (who, maddr) in [("worker", &b_maddr), ("router", &r_maddr)] {
        let body = scrape(maddr);
        lint_exposition(&body);
        for family in ["mpic_requests_total", "mpic_uptime_seconds", "mpic_ttft_seconds_count"] {
            assert!(body.contains(family), "{who} exposition missing {family}:\n{body}");
        }
        let inf = series_value(&body, "mpic_ttft_seconds_bucket{le=\"+Inf\"}")
            .unwrap_or_else(|| panic!("{who} has no +Inf ttft bucket:\n{body}"));
        let count = series_value(&body, "mpic_ttft_seconds_count").unwrap();
        assert_eq!(inf, count, "{who}: +Inf bucket must equal the count");
        assert!(count >= 1.0, "{who}: the traced request must be in the histogram");
    }

    // ------------------------------------------------------------------
    // Teardown.
    // ------------------------------------------------------------------
    let bye = cr.call(&v(r#"{"v":3,"id":"rbye","op":"shutdown"}"#)).unwrap();
    assert_ok(&bye);
    router_join.join().unwrap();
    drop(ca);
    drop(cb);
    shutdown_worker(a_addr, a_join);
    shutdown_worker(b_addr, b_join);
}

// ---------------------------------------------------------------------------
// Slow-request log (no artifacts needed: recorder-level)
// ---------------------------------------------------------------------------

static CAPTURED: Mutex<Vec<String>> = Mutex::new(Vec::new());

struct CaptureLogger;

impl log::Log for CaptureLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }
    fn log(&self, record: &log::Record) {
        if record.target() == "mpic::trace" {
            CAPTURED.lock().unwrap().push(record.args().to_string());
        }
    }
    fn flush(&self) {}
}

static LOGGER: CaptureLogger = CaptureLogger;

#[test]
fn slow_request_log_fires_over_threshold() {
    // This test binary installs its own logger (one global per process;
    // this file's other test never logs through it before assertions).
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(log::LevelFilter::Warn);

    let rec = Recorder::new(8);
    rec.set_slow_threshold(Some(Duration::ZERO));
    let id = TraceId(0xfeed);
    let t0 = Instant::now();
    rec.begin_at(id, "infer", t0);
    rec.record(id, "prefill", t0, Instant::now(), &[]);
    let (total_s, slow) = rec.finish(id).expect("active trace finishes");
    assert!(slow, "zero threshold marks every request slow");
    assert!(total_s >= 0.0);

    let lines = CAPTURED.lock().unwrap();
    let hit = lines.iter().find(|l| l.contains(&id.hex())).unwrap_or_else(|| {
        panic!("slow-request warning must fire through the log facade: {lines:?}")
    });
    assert!(hit.contains("slow request"), "{hit}");
    assert!(hit.contains("op=infer"), "{hit}");
    assert!(hit.contains("prefill"), "slow log lists span names: {hit}");
}
