#!/usr/bin/env python3
"""Generator for the golden container fixtures (container_v1.bin ... v6).

The fixtures are FROZEN: once checked in they must never be regenerated,
only new versions may be added -- rust/tests/container_golden.rs decodes
them byte-for-byte to prove the codec still reads every historical
container format. This script exists for provenance: it documents exactly
how the bytes were produced, using only the stdlib (the container's zstd
chunks are hand-built raw-block frames, so no zstd bindings are needed
and the compressed bytes are reproducible forever).

Tensor patterns mirror rust/tests/container_golden.rs exactly. The v6
fixture's rows each peak at the quantizer's qmax (127 for int8, 7 for
int4) so the row scale is exactly 1.0 and integer-valued floats survive
the quantize/dequantize round trip bit-exactly.
"""

import hashlib
import os
import struct

MODEL = "mpic-sim-a"
LAYERS, TOKENS, HEADS, D_HEAD, D_MODEL = 4, 2, 2, 2, 4
ROW = HEADS * D_HEAD  # 4: the quantizer's K/V row width
PER_LAYER = TOKENS * HEADS * D_HEAD  # 8 floats per layer per tensor
KV_ELEMS = LAYERS * PER_LAYER  # 32
EMB_ELEMS = TOKENS * D_MODEL  # 8


def le32(x):
    return struct.pack("<I", x)


def le64(x):
    return struct.pack("<Q", x)


def lestr(s):
    b = s.encode()
    return le32(len(b)) + b


def f32le(vals):
    return b"".join(struct.pack("<f", v) for v in vals)


def sha(b):
    return hashlib.sha256(b).digest()


def zstd_raw(data):
    """A standard zstd frame holding `data` as one raw (stored) block.

    magic | FHD=0x00 (no content size, no checksum, no dict)
    | window descriptor 0x00 (1 KiB window; raw blocks never back-ref)
    | 3-byte LE block header (size<<3 | type=0raw<<1 | last=1) | data
    """
    assert 0 < len(data) < 1024
    hdr = (len(data) << 3) | 1
    return b"\x28\xb5\x2f\xfd\x00\x00" + struct.pack("<I", hdr)[:3] + data


def dims():
    return b"".join(le32(d) for d in (LAYERS, TOKENS, HEADS, D_HEAD, D_MODEL))


def chunk_body(payload, chunk_size):
    """chunk_size u32 | n_chunks u32 | table | compressed chunks."""
    chunks = [payload[i : i + chunk_size] for i in range(0, len(payload), chunk_size)]
    comps = [zstd_raw(c) for c in chunks]
    table = b"".join(le32(len(c)) + sha(c) for c in comps)
    return le32(chunk_size) + le32(len(chunks)) + table + b"".join(comps), comps


# --- full-precision tensors (v1..v5): multiples of 0.25, exact in f32 ---

EMB_FP = [(i % 13) * 0.5 - 3.0 for i in range(EMB_ELEMS)]
K_FP = [((i * 3) % 17) * 0.25 - 2.0 for i in range(KV_ELEMS)]
V_FP = [((i * 7) % 19) * 0.25 - 2.25 for i in range(KV_ELEMS)]


# --- quant-exact tensors (v6): every row peaks at qmax, so scale = 1.0 ---


def q8(r, j):
    if j == 0:
        return 127.0 if r % 2 == 0 else -127.0
    return float((r * 31 + j * 7) % 200 - 100)


def q4(r, j):
    if j == 0:
        return 7.0 if r % 2 == 0 else -7.0
    return float((r * 5 + j * 3) % 15 - 7)


# K/V rows of layers 0..2 (rows 0..4, the int8 group) use the q8
# pattern; layers 2..4 (rows 4..8, the int4 group) use q4.
Q_SPLIT = 2 * PER_LAYER // ROW  # 4
EMB_Q = [q8(i // ROW, i % ROW) for i in range(EMB_ELEMS)]
K_Q = [
    q8(i // ROW, i % ROW) if i // ROW < Q_SPLIT else q4(i // ROW, i % ROW)
    for i in range(KV_ELEMS)
]
V_Q = [
    q8(i // ROW + 3, i % ROW) if i // ROW < Q_SPLIT else q4(i // ROW + 3, i % ROW)
    for i in range(KV_ELEMS)
]


def quant_section(vals, row, qmax):
    """Per-row scale (f32 LE) + int8 bytes / packed int4 nibbles.

    Asserts each row's max-abs equals qmax so scale is exactly 1.0 and
    codes equal the (integer-valued) inputs.
    """
    out = b""
    for r0 in range(0, len(vals), row):
        r = vals[r0 : r0 + row]
        assert max(abs(v) for v in r) == qmax, (r0, r)
        assert all(v == int(v) and abs(v) <= qmax for v in r), (r0, r)
        out += struct.pack("<f", 1.0)
        codes = [int(v) for v in r]
        if qmax == 127.0:
            out += bytes(c & 0xFF for c in codes)
        else:
            packed = []
            for i in range(0, len(codes), 2):
                qa = codes[i] & 0x0F
                qb = (codes[i + 1] & 0x0F) if i + 1 < len(codes) else 0
                packed.append(qa | (qb << 4))
            out += bytes(packed)
    return out


def prefix(version):
    return b"MPKV" + le32(version) + lestr(MODEL)


def build_v1():
    payload = f32le(EMB_FP + K_FP + V_FP)
    comp = zstd_raw(payload)
    return prefix(1) + le64(0x5101) + dims() + le64(len(comp)) + sha(comp) + comp


def build_v2():
    payload = f32le(EMB_FP + K_FP + V_FP)  # 288 bytes -> 2 chunks of 256
    body, _ = chunk_body(payload, 256)
    return prefix(2) + le64(0x5102) + dims() + body


def build_v3():
    payload = f32le(K_FP + V_FP)  # chunk entry: no emb, 256 bytes -> 1 chunk
    body, _ = chunk_body(payload, 256)
    return prefix(3) + b"c" + le64(0x5103) + dims() + b"\x00" + body


def build_v4():
    payload = f32le(EMB_FP + K_FP + V_FP)  # 288 bytes -> 3 chunks of 128
    body, _ = chunk_body(payload, 128)
    seg = b"i" + le64(0x5104) + dims() + b"\x01"
    return prefix(4) + lestr("tenant-gold") + seg + body


def build_v5():
    # Group-ordered payload, layers_per_group=2: g0 = emb ++ k/v layers
    # 0..2 (160 bytes), g1 = k/v layers 2..4 (128 bytes); chunk_size=96
    # so each group splits into chunks that never cross the boundary.
    g0 = f32le(EMB_FP + K_FP[: 2 * PER_LAYER] + V_FP[: 2 * PER_LAYER])
    g1 = f32le(K_FP[2 * PER_LAYER :] + V_FP[2 * PER_LAYER :])
    chunk_size = 96
    groups = [g0, g1]
    comps, counts = [], []
    for g in groups:
        cs = [zstd_raw(g[i : i + chunk_size]) for i in range(0, len(g), chunk_size)]
        counts.append(len(cs))
        comps.extend(cs)
    table = b"".join(le32(len(c)) + sha(c) for c in comps)
    seg = b"i" + le64(0x5105) + dims() + b"\x01"
    hdr = le32(2) + le32(2) + le32(chunk_size) + le32(sum(counts))
    hdr += b"".join(le32(n) for n in counts)
    return prefix(5) + lestr("") + seg + hdr + table + b"".join(comps)


def build_v6():
    # Same grouping as v5 but with quantized subpayloads: g0 int8
    # (scale+codes per row -> 16+32+32 = 80 bytes), g1 int4 (48 bytes).
    g0 = (
        quant_section(EMB_Q, D_MODEL, 127.0)
        + quant_section(K_Q[: 2 * PER_LAYER], ROW, 127.0)
        + quant_section(V_Q[: 2 * PER_LAYER], ROW, 127.0)
    )
    g1 = quant_section(K_Q[2 * PER_LAYER :], ROW, 7.0) + quant_section(
        V_Q[2 * PER_LAYER :], ROW, 7.0
    )
    assert len(g0) == 80 and len(g1) == 48, (len(g0), len(g1))
    chunk_size = 64
    comps, counts = [], []
    for g in (g0, g1):
        cs = [zstd_raw(g[i : i + chunk_size]) for i in range(0, len(g), chunk_size)]
        counts.append(len(cs))
        comps.extend(cs)
    table = b"".join(le32(len(c)) + sha(c) for c in comps)
    seg = b"i" + le64(0x5106) + dims() + b"\x01"
    hdr = le32(2) + le32(2) + le32(chunk_size) + le32(sum(counts))
    hdr += b"".join(le32(n) for n in counts)
    hdr += bytes([1, 2])  # per-group quant levels: int8, int4
    return prefix(6) + lestr("tenant-gold") + seg + hdr + table + b"".join(comps)


def main():
    here = os.path.dirname(os.path.abspath(__file__))
    builders = {
        "container_v1.bin": build_v1,
        "container_v2.bin": build_v2,
        "container_v3.bin": build_v3,
        "container_v4.bin": build_v4,
        "container_v5.bin": build_v5,
        "container_v6.bin": build_v6,
    }
    for name, build in builders.items():
        data = build()
        path = os.path.join(here, name)
        with open(path, "wb") as f:
            f.write(data)
        print(f"{name}: {len(data)} bytes sha256={hashlib.sha256(data).hexdigest()[:16]}")


if __name__ == "__main__":
    main()
