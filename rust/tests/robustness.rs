//! Robustness & failure-path coverage: configuration errors, capacity
//! overflows, malformed inputs, and randomized substrate fuzzing.

use mpic::coordinator::{Engine, EngineConfig, Policy};
use mpic::mm::{ImageId, Prompt, Tokenizer, UserId};
use mpic::util::json::Value;
use mpic::util::prop;
use mpic::util::rng::Rng;
use mpic::util::stats::{ecdf, Samples};

// ---------------------------------------------------------------------
// Substrate fuzzing (no PJRT needed)
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Value::Null,
        1 => Value::Bool(rng.bool(0.5)),
        2 => Value::Num((rng.next_u64() % 100_000) as f64 / 8.0),
        3 => {
            let n = rng.below(12) as usize;
            let s: String = (0..n)
                .map(|_| {
                    let c = rng.below(128) as u8;
                    if c.is_ascii_graphic() || c == b' ' { c as char } else { 'x' }
                })
                .collect();
            Value::str(s)
        }
        4 => Value::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Value::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    prop::check(
        "json-roundtrip-fuzz",
        200,
        |rng| random_json(rng, 3),
        |v| {
            let text = v.encode();
            let back = Value::parse(&text).map_err(|e| format!("parse: {e}"))?;
            if &back != v {
                return Err(format!("roundtrip mismatch: {text}"));
            }
            Ok(())
        },
    );
}

#[test]
fn json_rejects_malformed_inputs() {
    for bad in [
        "", "{", "}", "[1,2", "{\"a\":}", "{\"a\" 1}", "tru", "nul", "\"\\q\"",
        "[1,,2]", "{\"a\":1,}", "--3", "1e", "\u{0}",
    ] {
        assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn prop_stats_percentile_bounds() {
    prop::check(
        "stats-percentile-bounds",
        100,
        |rng| {
            let n = 1 + rng.below(200) as usize;
            (0..n).map(|_| rng.normal() * 10.0).collect::<Vec<f64>>()
        },
        |xs| {
            let mut s = Samples::new();
            for &x in xs {
                s.push(x);
            }
            let (mn, mx) = (s.min(), s.max());
            for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
                let v = s.percentile(p);
                if v < mn - 1e-9 || v > mx + 1e-9 {
                    return Err(format!("p{p} = {v} outside [{mn}, {mx}]"));
                }
            }
            if s.percentile(25.0) > s.percentile(75.0) {
                return Err("percentiles not monotone".into());
            }
            let cdf = ecdf(xs);
            if cdf.last().map(|&(_, f)| f) != Some(1.0) {
                return Err("ecdf must end at 1".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tokenizer_stability() {
    let tok = Tokenizer::new(4096);
    prop::check(
        "tokenizer-stability",
        100,
        |rng| {
            let n = rng.below(20) as usize;
            (0..n)
                .map(|_| format!("w{}", rng.below(1000)))
                .collect::<Vec<String>>()
                .join(" ")
        },
        |text| {
            let a = tok.encode(text);
            let b = tok.encode(text);
            if a != b {
                return Err("tokenizer not deterministic".into());
            }
            for &id in &a {
                if !(10..4096).contains(&id) {
                    return Err(format!("id {id} out of range"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Engine error paths (need artifacts)
// ---------------------------------------------------------------------

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn engine_error_paths() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built");
        return;
    }

    // Unknown model name fails fast with a clear message.
    let err = match Engine::new(EngineConfig {
        model: "no-such-model".into(),
        ..Default::default()
    }) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("unknown model must fail"),
    };
    assert!(err.contains("no-such-model"), "{err}");

    // Missing artifact dir fails fast.
    assert!(Engine::new(EngineConfig {
        artifact_dir: "/definitely/not/here".into(),
        ..Default::default()
    })
    .is_err());

    let dir = std::env::temp_dir().join(format!("mpic-robust-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let engine = Engine::new(EngineConfig {
        model: "mpic-sim-a".into(),
        store: mpic::kv::StoreConfig { disk_dir: dir, ..Default::default() },
        enforce_ownership: true,
        user_quota: 2,
        ..Default::default()
    })
    .unwrap();

    // Ownership enforcement: un-owned image is rejected.
    let foreign = Prompt::new(UserId(1)).text("look at").image(ImageId(999)).text("now");
    let err = engine.infer(&foreign, Policy::MpicK(8), 2).unwrap_err().to_string();
    assert!(err.contains("does not own"), "{err}");

    // Quota enforcement.
    engine.upload_image(UserId(1), "IMAGE#Q1").unwrap();
    engine.upload_image(UserId(1), "IMAGE#Q2").unwrap();
    let err = engine.upload_image(UserId(1), "IMAGE#Q3").unwrap_err().to_string();
    assert!(err.contains("quota"), "{err}");

    // Prompt exceeding the largest bucket is rejected cleanly.
    let mut huge = Prompt::new(UserId(1)).text("start");
    for i in 0..40 {
        // 40 images x 64 tokens > 2048-token bucket
        let h = format!("IMAGE#H{i}");
        let _ = engine.static_lib.register(UserId(2), &h, ImageId(5000 + i));
        huge = huge.image(ImageId(5000 + i));
    }
    let mut cfg2 = engine.config().clone();
    cfg2.enforce_ownership = false;
    drop(engine);
    let engine2 = Engine::new(cfg2).unwrap();
    let err = engine2.infer(&huge, Policy::Prefix, 2).unwrap_err().to_string();
    assert!(
        err.contains("bucket") || err.contains("exceeds"),
        "oversized prompt must fail cleanly: {err}"
    );

    // Full reuse requires the prompt to end with text.
    engine2.upload_image(UserId(3), "IMAGE#END").unwrap();
    let img_end = Prompt::new(UserId(3)).text("describe").image(ImageId::from_handle("IMAGE#END"));
    let err = engine2.infer(&img_end, Policy::FullReuse, 2).unwrap_err().to_string();
    assert!(err.contains("end with text"), "{err}");

    // MPIC with an enormous k still works (degenerates to exact).
    let ok = engine2
        .infer(
            &Prompt::new(UserId(3)).text("describe").image(ImageId::from_handle("IMAGE#END")).text("now"),
            Policy::MpicK(10_000),
            2,
        )
        .unwrap();
    assert_eq!(ok.tokens.len(), 2);

    println!("OK engine error paths");
}
