//! Protocol-conformance check: replay golden v1/v2/v3 request/reply line
//! fixtures from `rust/tests/data/` against a live server, so future API
//! changes that break old envelopes fail loudly instead of silently
//! shifting the wire contract.
//!
//! Fixture format: one JSON object per line,
//!
//! ```json
//! {"send": {...request...},
//!  "expect": {"field": exact-value, ...},          // subset match
//!  "expect_present": ["field", ...],               // must exist, any value
//!  "capture": {"name": "reply_field"}}             // remember for later lines
//! ```
//!
//! Later `send` objects may reference captured values as the string
//! `"${name}"` — how the v3 fixtures thread a dynamically granted lease
//! id through renew/release lines. Each fixture file replays on a fresh
//! connection against a fresh engine, strictly in order.

use std::collections::HashMap;

use mpic::coordinator::{Engine, EngineConfig};
use mpic::server::Client;
use mpic::util::json::Value;

fn artifacts_ready() -> bool {
    let ready = std::path::Path::new("artifacts/manifest.json").exists();
    if !ready && std::env::var("MPIC_REQUIRE_ARTIFACTS").map_or(false, |v| !v.is_empty()) {
        panic!("MPIC_REQUIRE_ARTIFACTS is set but artifacts/manifest.json is missing");
    }
    ready
}

fn test_engine(tag: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("mpic-golden-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Engine::new(EngineConfig {
        model: "mpic-sim-a".into(),
        store: mpic::kv::StoreConfig { disk_dir: dir, ..Default::default() },
        max_new_tokens: 4,
        ..Default::default()
    })
    .expect("engine")
}

/// Substitute `"${name}"` strings with captured reply values.
fn substitute(v: &Value, captured: &HashMap<String, Value>) -> Value {
    match v {
        Value::Str(s) if s.starts_with("${") && s.ends_with('}') => {
            let name = &s[2..s.len() - 1];
            captured
                .get(name)
                .unwrap_or_else(|| panic!("fixture references uncaptured value {name:?}"))
                .clone()
        }
        Value::Obj(m) => {
            Value::Obj(m.iter().map(|(k, x)| (k.clone(), substitute(x, captured))).collect())
        }
        Value::Arr(a) => Value::Arr(a.iter().map(|x| substitute(x, captured)).collect()),
        other => other.clone(),
    }
}

/// Replay one fixture file on a fresh connection; panic with the line
/// number and full reply on any divergence from the golden expectations.
fn replay(file: &str, addr: std::net::SocketAddr) {
    let path = std::path::Path::new("rust/tests/data").join(file);
    let content = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("golden fixture {} unreadable: {e}", path.display()));
    let mut c = Client::connect(addr).expect("connect");
    let mut captured: HashMap<String, Value> = HashMap::new();

    for (lineno, line) in content.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let ctx = format!("{file}:{}", lineno + 1);
        let fixture = Value::parse(line).unwrap_or_else(|e| panic!("{ctx}: bad fixture: {e}"));
        let send_raw = fixture.get("send").unwrap_or_else(|_| panic!("{ctx}: no send"));
        let send = substitute(send_raw, &captured);
        c.send(&send).unwrap_or_else(|e| panic!("{ctx}: send failed: {e}"));
        let reply = c.recv().unwrap_or_else(|e| panic!("{ctx}: no reply: {e}"));

        if let Some(Value::Obj(expect)) = fixture.opt("expect") {
            for (k, want) in expect {
                let got = reply.opt(k).unwrap_or_else(|| {
                    panic!("{ctx}: reply missing field {k:?}: {}", reply.encode())
                });
                assert_eq!(
                    got,
                    want,
                    "{ctx}: field {k:?} diverged from golden (got {}, want {}): {}",
                    got.encode(),
                    want.encode(),
                    reply.encode()
                );
            }
        }
        if let Some(Value::Arr(present)) = fixture.opt("expect_present") {
            for k in present {
                let k = k.as_str().unwrap_or_else(|e| panic!("{ctx}: bad expect_present: {e}"));
                assert!(
                    reply.opt(k).is_some(),
                    "{ctx}: reply missing expected field {k:?}: {}",
                    reply.encode()
                );
            }
        }
        if let Some(Value::Obj(caps)) = fixture.opt("capture") {
            for (name, field) in caps {
                let field = field.as_str().unwrap_or_else(|e| panic!("{ctx}: bad capture: {e}"));
                let val = reply.opt(field).unwrap_or_else(|| {
                    panic!("{ctx}: capture field {field:?} absent: {}", reply.encode())
                });
                captured.insert(name.clone(), val.clone());
            }
        }
    }
}

#[test]
fn wire_protocol_conformance() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    // The fixtures must exist even when the engine is unavailable — a
    // deleted fixture set would make this check silently vacuous.
    for file in ["wire_v1.jsonl", "wire_v2.jsonl", "wire_v3.jsonl"] {
        assert!(
            std::path::Path::new("rust/tests/data").join(file).exists(),
            "golden fixture {file} is missing"
        );
    }

    let engine = test_engine("wire");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let driver = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        for file in ["wire_v1.jsonl", "wire_v2.jsonl", "wire_v3.jsonl"] {
            replay(file, addr);
            println!("OK golden {file}");
        }
        let mut c = Client::connect(addr).unwrap();
        let bye = c.call(&Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert!(bye.get("ok").unwrap().as_bool().unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    driver.join().unwrap();
}
