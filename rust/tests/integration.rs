//! Cross-module integration tests that do NOT need the PJRT runtime:
//! workload → layout → selection → linker assembly → (synthetic) KV store
//! round trips, failure injection, and multi-turn session growth.

use std::sync::Arc;
use std::time::Duration;

use mpic::coordinator::linker::{Linker, PAD_POS};
use mpic::coordinator::selection::{plan, Policy};
use mpic::kv::store::{KvStore, StoreConfig};
use mpic::kv::{ImageKv, KvKey, KvShape, TransferEngine};
use mpic::mm::{ImageId, LinkedLayout, Prompt, Tokenizer, UserId};
use mpic::runtime::artifacts::{ModelMeta, WeightsMeta};
use mpic::util::rng::Rng;
use mpic::util::threadpool::ThreadPool;
use mpic::workload::{generate, Dataset, WorkloadSpec};

fn meta() -> ModelMeta {
    ModelMeta {
        name: "sim".into(),
        d_model: 16,
        n_layers: 3,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        vocab: 4096,
        img_tokens: 8,
        patch_dim: 8,
        rope_theta: 1e4,
        sink_sigma: 3.0,
        sink_tau: 8.0,
        bos_bias: 2.0,
        weights: WeightsMeta {
            file: String::new(),
            total_bytes: 0,
            sha256: String::new(),
            tensors: vec![],
        },
    }
}

fn synth_entry(meta: &ModelMeta, image: ImageId, seed: u64) -> ImageKv {
    let shape = KvShape {
        layers: meta.n_layers,
        tokens: meta.img_tokens,
        heads: meta.n_heads,
        d_head: meta.d_head,
        d_model: meta.d_model,
    };
    let mut rng = Rng::new(seed);
    ImageKv {
        key: KvKey::new(&meta.name, image),
        shape,
        emb: (0..shape.emb_elems()).map(|_| rng.normal() as f32).collect(),
        k: (0..shape.kv_elems()).map(|_| rng.normal() as f32).collect(),
        v: (0..shape.kv_elems()).map(|_| rng.normal() as f32).collect(),
    }
}

/// Workload → layout → MPIC plan → linker assembly, for every generated
/// conversation of both datasets: shapes, masks and padding must be
/// mutually consistent.
#[test]
fn workload_to_linker_pipeline() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let linker = Linker::new(&m);
    for dataset in [Dataset::Mmdu, Dataset::Sparkles] {
        let spec = WorkloadSpec {
            dataset,
            n_conversations: 10,
            turns_per_conversation: 2,
            images_min: 1,
            images_max: 4,
            seed: 7,
        };
        for conv in generate(&spec) {
            for turn in &conv.turns {
                let layout = LinkedLayout::build(turn, &tok, m.img_tokens, "sys prompt");
                let entries: Vec<ImageKv> = layout
                    .image_spans
                    .iter()
                    .map(|&(id, _, _)| synth_entry(&m, id, id.0))
                    .collect();
                let refs: Vec<&ImageKv> = entries.iter().collect();
                let bucket = layout.len().next_multiple_of(128);
                let pl = plan(Policy::MpicK(4), &layout, &[]);
                let (k, v) = linker.linked_cache(&layout, &refs, bucket).unwrap();
                let n_bucket = pl.selected.len().next_multiple_of(32);
                let si = linker.selective(&layout, &refs, &pl, k, v, bucket, n_bucket).unwrap();

                // Invariants.
                assert_eq!(si.n_selected, pl.selected.len());
                let sel_pos = si.sel_pos.i32_data().unwrap();
                let key_valid = si.key_valid.f32_data().unwrap();
                let key_pos = si.key_pos.i32_data().unwrap();
                for i in 0..layout.len() {
                    assert_eq!(key_valid[i], 1.0);
                    assert_eq!(key_pos[i], i as i32);
                }
                for i in layout.len()..bucket {
                    assert_eq!(key_valid[i], 0.0);
                    assert_eq!(key_pos[i], PAD_POS);
                }
                // Selected positions strictly increasing among real entries.
                for w in sel_pos[..pl.selected.len()].windows(2) {
                    assert!(w[0] < w[1]);
                }
            }
        }
    }
}

/// Linked-cache contents survive a store round trip through every tier.
#[test]
fn store_roundtrip_preserves_linker_output() {
    let m = meta();
    let dir = std::env::temp_dir().join(format!("mpic-int-roundtrip-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        KvStore::new(StoreConfig {
            disk_dir: dir,
            ttl: Duration::from_secs(60),
            ..Default::default()
        })
        .unwrap(),
    );
    let entry = synth_entry(&m, ImageId(5), 55);
    store.put(entry.clone()).unwrap();
    let (got, _) = store.get(&entry.key).unwrap();
    assert_eq!(*got, entry);
    // Evict then re-put.
    store.evict(&entry.key);
    assert!(store.get(&entry.key).is_none());
    store.put(entry.clone()).unwrap();
    let (got2, _) = store.get(&entry.key).unwrap();
    assert_eq!(*got2, entry);
}

/// Failure injection: expired TTL entries are recomputed by the transfer
/// engine, not served stale.
#[test]
fn transfer_recovers_from_expiry() {
    let m = meta();
    let dir = std::env::temp_dir().join(format!("mpic-int-ttl-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        KvStore::new(StoreConfig {
            disk_dir: dir,
            ttl: Duration::from_millis(1),
            device_capacity: 1, // nothing stays resident
            host_capacity: 1,
            shards: 1, // single shard so the LRU pressure below is exact
            ..Default::default()
        })
        .unwrap(),
    );
    let pool = Arc::new(ThreadPool::new(2));
    let engine = TransferEngine::new(pool);
    let key = KvKey::new(&m.name, ImageId(9));
    store.put(synth_entry(&m, ImageId(9), 9)).unwrap();
    // LRU-pressure the entry fully out of both RAM tiers (capacities are
    // 1 byte; the newest insert always displaces the older ones).
    store.put(synth_entry(&m, ImageId(10), 10)).unwrap();
    store.put(synth_entry(&m, ImageId(11), 11)).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    let mut recomputed = 0;
    let (out, _rep) = engine
        .fetch(&store, std::slice::from_ref(&key), |k| {
            recomputed += 1;
            Ok(synth_entry(&m, k.image, k.image.0))
        })
        .unwrap();
    assert_eq!(out.len(), 1);
    assert!(recomputed >= 1, "expired entry must be recomputed");
}

/// The two-step scatter path: text rows land exactly where the layout says.
#[test]
fn two_step_cache_assembly() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let p = Prompt::new(UserId(1))
        .text("alpha beta gamma")
        .image(ImageId(1))
        .text("delta epsilon");
    let layout = LinkedLayout::build(&p, &tok, m.img_tokens, "sys");
    let entry = synth_entry(&m, ImageId(1), 11);
    let refs = vec![&entry];
    let linker = Linker::new(&m);
    let bucket = 128;

    let (mut k, _v) = linker.linked_cache(&layout, &refs, bucket).unwrap();
    let (inputs, mapping) = linker.text_only_prefill(&layout, 128).unwrap();
    // Simulate a packed text-prefill output with recognisable values.
    let row = m.n_heads * m.d_head;
    let packed: Vec<f32> = (0..m.n_layers * 128 * row).map(|i| 1000.0 + i as f32).collect();
    linker.scatter_packed_rows(&mut k, bucket, &packed, 128, &mapping).unwrap();

    for (packed_idx, &slot) in mapping.iter().enumerate() {
        assert_eq!(k[slot * row], 1000.0 + (packed_idx * row) as f32);
    }
    let (_, lo, _) = layout.image_spans[0];
    assert_eq!(k[lo * row], entry.k[0]);
    let pos = inputs.positions.i32_data().unwrap();
    assert_eq!(pos[0], mapping[0] as i32);
}

/// Multi-turn sessions grow the layout monotonically and reuse image ids.
#[test]
fn session_layout_growth() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let mut store = mpic::coordinator::session::SessionStore::new();
    let user = UserId(3);
    let t1 = Prompt::new(user).text("first look at").image(ImageId(1));
    let full1 = store.session(user).user_turn(user, &t1);
    let l1 = LinkedLayout::build(&full1, &tok, m.img_tokens, "sys");
    store.session(user).assistant_reply(&[11, 12, 13]);
    let t2 = Prompt::new(user).text("now compare with").image(ImageId(2));
    let full2 = store.session(user).user_turn(user, &t2);
    let l2 = LinkedLayout::build(&full2, &tok, m.img_tokens, "sys");
    assert!(l2.len() > l1.len());
    assert_eq!(l2.image_spans.len(), 2);
    assert_eq!(l2.image_spans[0].0, ImageId(1));
    assert_eq!(l2.image_spans[1].0, ImageId(2));
}
