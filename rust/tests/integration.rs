//! Cross-module integration tests that do NOT need the PJRT runtime:
//! workload → layout → selection → linker assembly → (synthetic) KV store
//! round trips, failure injection, and multi-turn session growth.

use std::sync::Arc;
use std::time::Duration;

use mpic::coordinator::linker::{Linker, PAD_POS};
use mpic::coordinator::selection::{plan, Policy};
use mpic::kv::store::{KvStore, StoreConfig};
use mpic::kv::{KvKey, KvShape, SegmentKv, TransferEngine};
use mpic::mm::{
    ChunkId, ChunkRef, ImageId, LinkedLayout, Prompt, Segment, SegmentId, Tokenizer, UserId,
};
use mpic::runtime::artifacts::{ModelMeta, WeightsMeta};
use mpic::util::rng::Rng;
use mpic::util::threadpool::ThreadPool;
use mpic::workload::{generate, rag_chunk_pool, Dataset, WorkloadSpec};

fn meta() -> ModelMeta {
    ModelMeta {
        name: "sim".into(),
        d_model: 16,
        n_layers: 3,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        vocab: 4096,
        img_tokens: 8,
        patch_dim: 8,
        rope_theta: 1e4,
        sink_sigma: 3.0,
        sink_tau: 8.0,
        bos_bias: 2.0,
        weights: WeightsMeta {
            file: String::new(),
            total_bytes: 0,
            sha256: String::new(),
            tensors: vec![],
        },
    }
}

fn synth_entry(meta: &ModelMeta, image: ImageId, seed: u64) -> SegmentKv {
    let shape = KvShape {
        layers: meta.n_layers,
        tokens: meta.img_tokens,
        heads: meta.n_heads,
        d_head: meta.d_head,
        d_model: meta.d_model,
    };
    let mut rng = Rng::new(seed);
    SegmentKv {
        key: KvKey::image(&meta.name, image),
        shape,
        emb: (0..shape.emb_elems()).map(|_| rng.normal() as f32).collect(),
        k: (0..shape.kv_elems()).map(|_| rng.normal() as f32).collect(),
        v: (0..shape.kv_elems()).map(|_| rng.normal() as f32).collect(),
    }
}

fn synth_chunk_entry(meta: &ModelMeta, chunk: ChunkId, tokens: usize, seed: u64) -> SegmentKv {
    let shape = KvShape {
        layers: meta.n_layers,
        tokens,
        heads: meta.n_heads,
        d_head: meta.d_head,
        d_model: meta.d_model,
    };
    let mut rng = Rng::new(seed);
    SegmentKv {
        key: KvKey::chunk(&meta.name, chunk),
        shape,
        emb: Vec::new(),
        k: (0..shape.kv_elems()).map(|_| rng.normal() as f32).collect(),
        v: (0..shape.kv_elems()).map(|_| rng.normal() as f32).collect(),
    }
}

/// Entry for any span kind (images get embeddings, chunks don't).
fn entry_for_span(meta: &ModelMeta, span: &mpic::mm::ReuseSpan) -> SegmentKv {
    match span.seg {
        SegmentId::Image(id) => synth_entry(meta, id, id.0),
        SegmentId::Chunk(id) => synth_chunk_entry(meta, id, span.len(), id.0),
    }
}

/// Engine-less chunk resolution for generated RAG prompts: substitute the
/// canonical token streams from the pool.
fn resolve_prompt(prompt: &Prompt, tok: &Tokenizer, pool: &[(String, String)]) -> Prompt {
    let mut out = prompt.clone();
    for seg in out.segments.iter_mut() {
        if let Segment::Chunk(c) = seg {
            if !c.is_resolved() {
                let (_, text) = pool
                    .iter()
                    .find(|(h, _)| ChunkId::from_handle(h) == c.id)
                    .expect("generated chunk ref must come from the pool");
                c.tokens = Arc::new(tok.encode(text));
            }
        }
    }
    out
}

/// Workload → layout → MPIC plan → linker assembly, for every generated
/// conversation of all three datasets (RAG included — chunk spans flow
/// through the same plan/link path): shapes, masks and padding must be
/// mutually consistent.
#[test]
fn workload_to_linker_pipeline() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let linker = Linker::new(&m);
    for dataset in [Dataset::Mmdu, Dataset::Sparkles, Dataset::Rag] {
        let spec = WorkloadSpec {
            dataset,
            n_conversations: 10,
            turns_per_conversation: 2,
            images_min: 1,
            images_max: 4,
            seed: 7,
        };
        let pool = rag_chunk_pool(&spec);
        for conv in generate(&spec) {
            for turn in &conv.turns {
                let turn = resolve_prompt(turn, &tok, &pool);
                let layout = LinkedLayout::build(&turn, &tok, m.img_tokens, "sys prompt");
                let entries: Vec<SegmentKv> =
                    layout.reuse_spans.iter().map(|s| entry_for_span(&m, s)).collect();
                let refs: Vec<&SegmentKv> = entries.iter().collect();
                let bucket = layout.len().next_multiple_of(128);
                let pl = plan(Policy::MpicK(4), &layout, &[]);
                let (k, v) = linker.linked_cache(&layout, &refs, bucket).unwrap();
                let n_bucket = pl.selected.len().next_multiple_of(32);
                let si = linker.selective(&layout, &refs, &pl, k, v, bucket, n_bucket).unwrap();

                // Invariants.
                assert_eq!(si.n_selected, pl.selected.len());
                let sel_pos = si.sel_pos.i32_data().unwrap();
                let key_valid = si.key_valid.f32_data().unwrap();
                let key_pos = si.key_pos.i32_data().unwrap();
                for i in 0..layout.len() {
                    assert_eq!(key_valid[i], 1.0);
                    assert_eq!(key_pos[i], i as i32);
                }
                for i in layout.len()..bucket {
                    assert_eq!(key_valid[i], 0.0);
                    assert_eq!(key_pos[i], PAD_POS);
                }
                // Selected positions strictly increasing among real entries.
                for w in sel_pos[..pl.selected.len()].windows(2) {
                    assert!(w[0] < w[1]);
                }
            }
        }
    }
}

/// RAG reuse shape end to end (engine-less): two conversations sharing a
/// chunk produce layouts that place the same chunk at *different* linked
/// positions, and one synthetic store entry serves both via the transfer
/// engine with no recompute.
#[test]
fn shared_chunk_links_at_different_positions() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let doc = "shared festival report describing the harbour celebrations in detail";
    let toks = tok.encode(doc);
    let chunk = ChunkId::from_handle("CHUNK#SHARED");
    let p1 = Prompt::new(UserId(1))
        .text("short opener")
        .chunk(ChunkRef::resolved(chunk, toks.clone()))
        .text("question one please");
    let p2 = Prompt::new(UserId(2))
        .text("a much longer and completely different opening sentence here")
        .chunk(ChunkRef::resolved(chunk, toks.clone()))
        .text("question two");
    let l1 = LinkedLayout::build(&p1, &tok, m.img_tokens, "sys");
    let l2 = LinkedLayout::build(&p2, &tok, m.img_tokens, "sys");
    let s1 = l1.reuse_spans[0];
    let s2 = l2.reuse_spans[0];
    assert_eq!(s1.seg, s2.seg);
    assert_ne!(s1.lo, s2.lo, "different openers must shift the span");
    assert_eq!(s1.len(), s2.len());

    // One stored entry serves both layouts through the transfer engine.
    let dir = std::env::temp_dir().join(format!("mpic-int-chunkshare-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        KvStore::new(StoreConfig { disk_dir: dir, ..Default::default() }).unwrap(),
    );
    let entry = synth_chunk_entry(&m, chunk, toks.len(), 99);
    store.put(entry.clone()).unwrap();
    let pool = Arc::new(ThreadPool::new(2));
    let eng = TransferEngine::new(pool);
    let linker = Linker::new(&m);
    for l in [&l1, &l2] {
        let keys: Vec<KvKey> =
            l.reuse_spans
                .iter()
                .map(|s| KvKey { model: m.name.clone(), ns: Default::default(), seg: s.seg })
                .collect();
        let (got, rep) = eng
            .fetch(&store, &keys, |_| panic!("must be a store hit"))
            .unwrap();
        assert_eq!(rep.misses, 0);
        let refs: Vec<&SegmentKv> = got.iter().map(|e| e.as_ref()).collect();
        // The same rows land at the layout's own span positions.
        let (k, _) = linker.linked_cache(l, &refs, l.len().next_multiple_of(128)).unwrap();
        let row = m.n_heads * m.d_head;
        let lo = l.reuse_spans[0].lo;
        assert_eq!(&k[lo * row..lo * row + row], &entry.k[0..row]);
    }
}

/// Linked-cache contents survive a store round trip through every tier.
#[test]
fn store_roundtrip_preserves_linker_output() {
    let m = meta();
    let dir = std::env::temp_dir().join(format!("mpic-int-roundtrip-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        KvStore::new(StoreConfig {
            disk_dir: dir,
            ttl: Duration::from_secs(60),
            ..Default::default()
        })
        .unwrap(),
    );
    let entry = synth_entry(&m, ImageId(5), 55);
    store.put(entry.clone()).unwrap();
    let (got, _) = store.get(&entry.key).unwrap();
    assert_eq!(*got, entry);
    // Evict then re-put.
    store.evict(&entry.key);
    assert!(store.get(&entry.key).is_none());
    store.put(entry.clone()).unwrap();
    let (got2, _) = store.get(&entry.key).unwrap();
    assert_eq!(*got2, entry);
}

/// Failure injection: expired TTL entries are recomputed by the transfer
/// engine, not served stale.
#[test]
fn transfer_recovers_from_expiry() {
    let m = meta();
    let dir = std::env::temp_dir().join(format!("mpic-int-ttl-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(
        KvStore::new(StoreConfig {
            disk_dir: dir,
            ttl: Duration::from_millis(1),
            device_capacity: 1, // nothing stays resident
            host_capacity: 1,
            shards: 1, // single shard so the LRU pressure below is exact
            ..Default::default()
        })
        .unwrap(),
    );
    let pool = Arc::new(ThreadPool::new(2));
    let engine = TransferEngine::new(pool);
    let key = KvKey::image(&m.name, ImageId(9));
    store.put(synth_entry(&m, ImageId(9), 9)).unwrap();
    // LRU-pressure the entry fully out of both RAM tiers (capacities are
    // 1 byte; the newest insert always displaces the older ones).
    store.put(synth_entry(&m, ImageId(10), 10)).unwrap();
    store.put(synth_entry(&m, ImageId(11), 11)).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    let mut recomputed = 0;
    let (out, _rep) = engine
        .fetch(&store, std::slice::from_ref(&key), |k| {
            recomputed += 1;
            let img = k.seg.as_image().unwrap();
            Ok(synth_entry(&m, img, img.0))
        })
        .unwrap();
    assert_eq!(out.len(), 1);
    assert!(recomputed >= 1, "expired entry must be recomputed");
}

/// The two-step scatter path: text rows land exactly where the layout says.
#[test]
fn two_step_cache_assembly() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let p = Prompt::new(UserId(1))
        .text("alpha beta gamma")
        .image(ImageId(1))
        .text("delta epsilon");
    let layout = LinkedLayout::build(&p, &tok, m.img_tokens, "sys");
    let entry = synth_entry(&m, ImageId(1), 11);
    let refs = vec![&entry];
    let linker = Linker::new(&m);
    let bucket = 128;

    let (mut k, _v) = linker.linked_cache(&layout, &refs, bucket).unwrap();
    let (inputs, mapping) = linker.text_only_prefill(&layout, 128).unwrap();
    // Simulate a packed text-prefill output with recognisable values.
    let row = m.n_heads * m.d_head;
    let packed: Vec<f32> = (0..m.n_layers * 128 * row).map(|i| 1000.0 + i as f32).collect();
    linker.scatter_packed_rows(&mut k, bucket, &packed, 128, &mapping).unwrap();

    for (packed_idx, &slot) in mapping.iter().enumerate() {
        assert_eq!(k[slot * row], 1000.0 + (packed_idx * row) as f32);
    }
    let lo = layout.reuse_spans[0].lo;
    assert_eq!(k[lo * row], entry.k[0]);
    let pos = inputs.positions.i32_data().unwrap();
    assert_eq!(pos[0], mapping[0] as i32);
}

/// Multi-turn sessions grow the layout monotonically and reuse image ids.
#[test]
fn session_layout_growth() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let mut store = mpic::coordinator::session::SessionStore::new();
    let user = UserId(3);
    let t1 = Prompt::new(user).text("first look at").image(ImageId(1));
    let full1 = store.session(&Default::default(), user).user_turn(user, &t1);
    let l1 = LinkedLayout::build(&full1, &tok, m.img_tokens, "sys");
    store.session(&Default::default(), user).assistant_reply(&[11, 12, 13]);
    let t2 = Prompt::new(user).text("now compare with").image(ImageId(2));
    let full2 = store.session(&Default::default(), user).user_turn(user, &t2);
    let l2 = LinkedLayout::build(&full2, &tok, m.img_tokens, "sys");
    assert!(l2.len() > l1.len());
    assert_eq!(l2.reuse_spans.len(), 2);
    assert_eq!(l2.reuse_spans[0].seg, SegmentId::Image(ImageId(1)));
    assert_eq!(l2.reuse_spans[1].seg, SegmentId::Image(ImageId(2)));
}
