//! Runtime end-to-end tests: execute the real AOT artifacts through PJRT.
//!
//! These are the cross-language correctness checks: the Rust linker +
//! runtime must reproduce the algebraic identities pytest established for
//! the JAX model (selective(all)==full, stored image KV == prefix prefill,
//! stale reuse diverges).
//!
//! PJRT handles are thread-bound (`Rc`), so everything runs inside ONE
//! test function, sequentially. Skips (with a message) when `artifacts/`
//! has not been built.

use mpic::coordinator::{Engine, EngineConfig, Policy};
use mpic::kv::KvKey;
use mpic::mm::{ChunkId, ImageId, Prompt, SegmentId, UserId};
use mpic::quality;

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn test_engine(model: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("mpic-e2e-{}-{model}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = EngineConfig {
        model: model.into(),
        store: mpic::kv::StoreConfig { disk_dir: dir, ..Default::default() },
        max_new_tokens: 8,
        ..Default::default()
    };
    Engine::new(cfg).expect("engine (artifacts built?)")
}

#[test]
fn runtime_end_to_end() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let engine = test_engine("mpic-sim-a");

    check_encode_deterministic(&engine);
    check_upload_and_store(&engine);
    check_prefix_inference(&engine);
    check_mpic_full_selection_is_exact(&engine);
    check_full_reuse_diverges_but_mpic_recovers(&engine);
    check_two_step_overhead_visible(&engine);
    check_multi_image_scaling(&engine);
    check_mrag_path(&engine);
    check_chunk_segment_caching(&engine);
    check_mrag_chunk_splicing(&engine);
    check_debug_attention_sinks(&engine);
}

fn two_image_prompt(user: UserId) -> Prompt {
    Prompt::new(user)
        .text("my partner and I took these photos near the river")
        .image(ImageId::from_handle("IMAGE#EIFFEL2025"))
        .image(ImageId::from_handle("IMAGE#LOUVRE2025"))
        .text("please describe the landmarks and compare them in detail for our travel notes")
}

fn check_encode_deterministic(engine: &Engine) {
    let a = engine.encode_image(ImageId(77)).unwrap();
    let b = engine.encode_image(ImageId(77)).unwrap();
    assert_eq!(a, b, "encode_image_kv must be deterministic");
    let c = engine.encode_image(ImageId(78)).unwrap();
    assert_ne!(a.k, c.k, "different images must encode differently");
    println!("OK encode_deterministic");
}

fn check_upload_and_store(engine: &Engine) {
    let user = UserId(1);
    let img = engine.upload_image(user, "IMAGE#EIFFEL2025").unwrap();
    engine.upload_image(user, "IMAGE#LOUVRE2025").unwrap();
    assert!(engine.static_lib.owns(user, img));
    let key = KvKey::image(&engine.meta().name, img);
    assert!(engine.store().contains(&key));
    // Disk write-through happened.
    let (_, _, disk_entries) = engine.store().residency();
    assert!(disk_entries >= 2);
    println!("OK upload_and_store");
}

fn check_prefix_inference(engine: &Engine) {
    let r = engine.infer(&two_image_prompt(UserId(1)), Policy::Prefix, 8).unwrap();
    assert_eq!(r.tokens.len(), 8);
    assert!(r.first_logits.len() == engine.meta().vocab);
    assert!(r.first_logits.iter().all(|x| x.is_finite()));
    assert!(r.ttft.total_s > 0.0);
    assert_eq!(r.ttft.steps, 1);
    println!("OK prefix_inference: ttft={:.1}ms", r.ttft.total_s * 1e3);
}

/// MPIC-k with k >= img_tokens recomputes *every* token → must equal the
/// exact prefix output up to float tolerance.
fn check_mpic_full_selection_is_exact(engine: &Engine) {
    let prompt = two_image_prompt(UserId(1));
    let reference = engine.infer(&prompt, Policy::Prefix, 8).unwrap();
    let k_all = engine.meta().img_tokens; // selects all image tokens
    let candidate = engine.infer(&prompt, Policy::MpicK(k_all), 8).unwrap();
    let s = quality::score(&reference, &candidate);
    assert!(
        s.kl_first < 1e-3,
        "MPIC with full selection must match exact output, KL={}",
        s.kl_first
    );
    assert_eq!(reference.tokens, candidate.tokens, "greedy tokens must agree");
    assert!(s.score > 9.9);
    println!("OK mpic_full_selection_is_exact: KL={:.2e}", s.kl_first);
}

fn check_full_reuse_diverges_but_mpic_recovers(engine: &Engine) {
    let prompt = two_image_prompt(UserId(1));
    let reference = engine.infer(&prompt, Policy::Prefix, 8).unwrap();
    let full_reuse = engine.infer(&prompt, Policy::FullReuse, 8).unwrap();
    let mpic32 = engine.infer(&prompt, Policy::MpicK(32), 8).unwrap();

    let s_fr = quality::score(&reference, &full_reuse);
    let s_mp = quality::score(&reference, &mpic32);
    assert!(
        s_fr.kl_first > 1e-4,
        "full reuse must diverge from the exact output (KL={})",
        s_fr.kl_first
    );
    assert!(
        s_mp.kl_first < s_fr.kl_first,
        "MPIC-32 (KL={}) must be closer to exact than full reuse (KL={})",
        s_mp.kl_first,
        s_fr.kl_first
    );
    println!(
        "OK divergence ordering: full_reuse KL={:.3e} > mpic-32 KL={:.3e}",
        s_fr.kl_first, s_mp.kl_first
    );
}

/// Step-count honesty: full-reuse = 2 engine calls, MPIC = 1, CacheBlend = 3.
fn check_two_step_overhead_visible(engine: &Engine) {
    let prompt = two_image_prompt(UserId(1));
    let fr = engine.infer(&prompt, Policy::FullReuse, 2).unwrap();
    let mp = engine.infer(&prompt, Policy::MpicK(32), 2).unwrap();
    let cb = engine.infer(&prompt, Policy::CacheBlend(15.0), 2).unwrap();
    assert_eq!(fr.ttft.steps, 2);
    assert_eq!(mp.ttft.steps, 1);
    assert_eq!(cb.ttft.steps, 3);
    println!("OK step counts: full-reuse=2 mpic=1 cacheblend=3");
}

fn check_multi_image_scaling(engine: &Engine) {
    // 6 images: selective bucket must still resolve, outputs finite.
    let user = UserId(2);
    let mut prompt = Prompt::new(user).text("compare all of these scenes");
    for i in 0..6 {
        let handle = format!("IMAGE#SCALE{i}");
        engine.upload_image(user, &handle).unwrap();
        prompt = prompt.image(ImageId::from_handle(&handle));
    }
    prompt = prompt.text("which is the most interesting and why");
    let r = engine.infer(&prompt, Policy::MpicK(8), 4).unwrap();
    assert!(r.seq_len > 6 * engine.meta().img_tokens);
    assert!(r.first_logits.iter().all(|x| x.is_finite()));
    println!("OK multi_image_scaling: seq_len={} bucket={}", r.seq_len, r.s_bucket);
}

fn check_mrag_path(engine: &Engine) {
    engine.add_reference("IMAGE#HOTEL01", "hotel lobby near the eiffel tower in paris").unwrap();
    engine.add_reference("IMAGE#HOTEL02", "budget hostel by the louvre museum").unwrap();
    engine.add_reference("IMAGE#BIKE01", "dirt bike race in the desert").unwrap();
    let prompt = Prompt::new(UserId(1)).text("recommend hotels near the eiffel tower please");
    let (augmented, ids) = engine.mrag_augment(&prompt, 2).unwrap();
    assert_eq!(ids.len(), 2);
    assert!(ids.contains(&SegmentId::Image(ImageId::from_handle("IMAGE#HOTEL01"))));
    let r = engine.infer(&augmented, Policy::MpicK(16), 4).unwrap();
    assert!(r.first_logits.iter().all(|x| x.is_finite()));
    println!("OK mrag_path: retrieved {ids:?}");
}

/// The acceptance e2e for position-independent segment caching: two
/// requests with *different opening text* but the same cached chunk +
/// image must both serve from the store (no re-encode of either segment),
/// and mpic-k must recompute exactly the first k tokens of each reusable
/// span.
fn check_chunk_segment_caching(engine: &Engine) {
    let user = UserId(5);
    let doc = "The harbour festival report describes boats, stalls and the evening \
               fireworks across three separate quays in considerable detail";
    let chunk = engine.upload_chunk("CHUNK#FESTIVAL", doc).unwrap();
    engine.upload_image(user, "IMAGE#QUAY01").unwrap();
    assert!(engine.stored_chunk_kv(chunk).is_some(), "chunk KV must be stored");

    let prompts = [
        Prompt::parse(user, "Summarise briefly: CHUNK#FESTIVAL and the photo IMAGE#QUAY01 please"),
        Prompt::parse(
            user,
            "We are planning a very different visit next year — given CHUNK#FESTIVAL \
             and IMAGE#QUAY01 what changed",
        ),
    ];
    let k = 8usize;
    let t = engine.meta().img_tokens;
    for (i, p) in prompts.iter().enumerate() {
        let layout = engine.layout(p).unwrap();
        assert_eq!(layout.reuse_spans.len(), 2);
        let chunk_len = layout
            .reuse_spans
            .iter()
            .find(|s| s.seg == SegmentId::Chunk(chunk))
            .unwrap()
            .len();
        let r = engine.infer(p, Policy::MpicK(k), 4).unwrap();
        // No re-encode of either segment: both were uploaded upfront.
        assert_eq!(r.transfer.misses, 0, "request {i} must not recompute any segment");
        assert_eq!(r.transfer.device_hits + r.transfer.host_hits + r.transfer.disk_hits, 2);
        // MPIC-k recomputes exactly text + the first k tokens of EVERY
        // reusable span (chunk included), nothing more.
        let expect = layout.text_len() + k.min(chunk_len) + k.min(t);
        assert_eq!(
            r.n_selected, expect,
            "request {i}: selected {} tokens, expected text {} + chunk head {} + image head {}",
            r.n_selected,
            layout.text_len(),
            k.min(chunk_len),
            k.min(t)
        );
        assert!(r.first_logits.iter().all(|x| x.is_finite()));
    }
    // The two prompts place the shared spans at different linked
    // positions — the reuse was position-independent.
    let l0 = engine.layout(&prompts[0]).unwrap();
    let l1 = engine.layout(&prompts[1]).unwrap();
    assert_ne!(l0.reuse_spans[0].lo, l1.reuse_spans[0].lo);

    // Exactness: with k covering every span token, MPIC equals prefix.
    let reference = engine.infer(&prompts[0], Policy::Prefix, 4).unwrap();
    let l0_max_span = l0.reuse_spans.iter().map(|s| s.len()).max().unwrap();
    let full = engine.infer(&prompts[0], Policy::MpicK(l0_max_span), 4).unwrap();
    let s = quality::score(&reference, &full);
    assert!(s.kl_first < 1e-3, "full selection over chunks must be exact, KL={}", s.kl_first);
    // Full reuse also runs over chunk spans (two-step path).
    let fr = engine.infer(&prompts[0], Policy::FullReuse, 4).unwrap();
    assert_eq!(fr.ttft.steps, 2);
    println!("OK chunk_segment_caching: chunk span reused at shifted positions, exact at full k");
}

/// MRAG over chunk references: retrieval splices the cached chunk KV
/// (not raw text) into the prompt.
fn check_mrag_chunk_splicing(engine: &Engine) {
    engine
        .add_chunk_reference(
            "CHUNK#GUIDE",
            "A guidebook chapter recommending quiet riverside walks near the old harbour",
            "guidebook chapter about riverside walks near the harbour",
        )
        .unwrap();
    let prompt = Prompt::new(UserId(1)).text("suggest riverside walks near the harbour");
    let (augmented, ids) = engine.mrag_augment(&prompt, 1).unwrap();
    let chunk = ChunkId::from_handle("CHUNK#GUIDE");
    assert_eq!(ids, vec![SegmentId::Chunk(chunk)]);
    let layout = engine.layout(&augmented).unwrap();
    assert!(layout.reuse_spans.iter().any(|s| s.seg == SegmentId::Chunk(chunk)));
    let r = engine.infer(&augmented, Policy::MpicK(8), 4).unwrap();
    assert_eq!(r.transfer.misses, 0, "retrieved chunk must hit the store");
    assert!(r.first_logits.iter().all(|x| x.is_finite()));
    println!("OK mrag_chunk_splicing: cached chunk spliced via retrieval");
}

/// Insight 2 must hold through the full Rust→PJRT path: early image tokens
/// receive the bulk of the last query's attention mass.
fn check_debug_attention_sinks(engine: &Engine) {
    let (layout, attn_last, attn_l0) =
        engine.debug_attention(&two_image_prompt(UserId(1))).unwrap();
    let meta = engine.meta();
    let data = attn_last.f32_data().unwrap();
    let s = data.len() / (meta.n_layers * meta.n_heads);
    let t = meta.img_tokens;
    let (lo, hi) = (layout.reuse_spans[0].lo, layout.reuse_spans[0].hi);
    let mut head_mass = 0f64;
    let mut tail_mass = 0f64;
    for l in 0..meta.n_layers {
        for h in 0..meta.n_heads {
            let base = (l * meta.n_heads + h) * s;
            for i in lo..hi {
                let m = data[base + i] as f64;
                if i < lo + t / 4 {
                    head_mass += m;
                } else {
                    tail_mass += m;
                }
            }
        }
    }
    assert!(
        head_mass > tail_mass,
        "first quarter of image tokens must dominate attention: head={head_mass} tail={tail_mass}"
    );
    // The layer-0 full matrix is a proper distribution per (valid) row.
    let l0 = attn_l0.f32_data().unwrap();
    let last_row = layout.len() - 1;
    let row: f32 = l0[last_row * s..(last_row + 1) * s].iter().sum();
    assert!((row - 1.0).abs() < 1e-3, "attention row sums to {row}");
    println!("OK debug_attention_sinks: head={head_mass:.3} tail={tail_mass:.3}");
}
