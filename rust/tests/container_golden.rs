//! Container-format conformance: decode the checked-in golden v1…v6
//! codec containers byte-for-byte, so a version-sniffing or layout
//! change that would strand old host/disk/peer containers fails loudly
//! here instead of silently invalidating every persisted cache.
//!
//! The fixtures in `rust/tests/data/container_v*.bin` are FROZEN — they
//! were produced once by `gen_containers.py` (stdlib-only, hand-built
//! raw-block zstd frames, see that file for provenance) and must never
//! be regenerated; only new versions may be added. The tensor patterns
//! below mirror the generator exactly: full-precision values are
//! multiples of 0.25 (exact in f32), and the v6 quantized rows each
//! peak at the quantizer's qmax so the row scale is exactly 1.0 and the
//! integer-valued floats survive the int8/int4 round trip bit-exactly.

use mpic::kv::codec;
use mpic::kv::{KvKey, KvShape, QuantLevel};
use mpic::mm::{ChunkId, ImageId, Namespace, SegmentId};

const MODEL: &str = "mpic-sim-a";
/// tokens * heads * d_head: floats per layer in each of K and V.
const PER_LAYER: usize = 8;
/// heads * d_head: the quantizer's K/V row width.
const ROW: usize = 4;

fn shape() -> KvShape {
    KvShape { layers: 4, tokens: 2, heads: 2, d_head: 2, d_model: 4 }
}

// --- full-precision tensors (v1..v5): multiples of 0.25, exact in f32 ---

fn emb_fp() -> Vec<f32> {
    (0..8).map(|i| (i % 13) as f32 * 0.5 - 3.0).collect()
}

fn k_fp() -> Vec<f32> {
    (0..32).map(|i| ((i * 3) % 17) as f32 * 0.25 - 2.0).collect()
}

fn v_fp() -> Vec<f32> {
    (0..32).map(|i| ((i * 7) % 19) as f32 * 0.25 - 2.25).collect()
}

// --- quant-exact tensors (v6): every row peaks at qmax, scale = 1.0 ---

fn q8(r: usize, j: usize) -> f32 {
    let peak = if r % 2 == 0 { 127.0 } else { -127.0 };
    if j == 0 {
        peak
    } else {
        ((r * 31 + j * 7) % 200) as f32 - 100.0
    }
}

fn q4(r: usize, j: usize) -> f32 {
    let peak = if r % 2 == 0 { 7.0 } else { -7.0 };
    if j == 0 {
        peak
    } else {
        ((r * 5 + j * 3) % 15) as f32 - 7.0
    }
}

fn emb_q() -> Vec<f32> {
    (0..8).map(|i| q8(i / ROW, i % ROW)).collect()
}

/// K/V rows of layers 0..2 (rows 0..4, the int8 group) follow the q8
/// pattern; layers 2..4 (rows 4..8, the int4 group) follow q4.
const Q_SPLIT: usize = 2 * PER_LAYER / ROW;

fn k_q() -> Vec<f32> {
    (0..32)
        .map(|i| {
            let (r, j) = (i / ROW, i % ROW);
            if r < Q_SPLIT {
                q8(r, j)
            } else {
                q4(r, j)
            }
        })
        .collect()
}

fn v_q() -> Vec<f32> {
    (0..32)
        .map(|i| {
            let (r, j) = (i / ROW, i % ROW);
            if r < Q_SPLIT {
                q8(r + 3, j)
            } else {
                q4(r + 3, j)
            }
        })
        .collect()
}

fn load(file: &str) -> Vec<u8> {
    let path = std::path::Path::new("rust/tests/data").join(file);
    std::fs::read(&path)
        .unwrap_or_else(|e| panic!("golden container {} unreadable: {e}", path.display()))
}

struct Golden {
    file: &'static str,
    version: u32,
    key: KvKey,
    has_emb: bool,
    n_groups: usize,
    max_quant: QuantLevel,
    emb: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
}

fn goldens() -> Vec<Golden> {
    let gold_ns = Namespace::new("tenant-gold").unwrap();
    vec![
        Golden {
            file: "container_v1.bin",
            version: 1,
            key: KvKey::image(MODEL, ImageId(0x5101)),
            has_emb: true,
            n_groups: 1,
            max_quant: QuantLevel::None,
            emb: emb_fp(),
            k: k_fp(),
            v: v_fp(),
        },
        Golden {
            file: "container_v2.bin",
            version: 2,
            key: KvKey::image(MODEL, ImageId(0x5102)),
            has_emb: true,
            n_groups: 1,
            max_quant: QuantLevel::None,
            emb: emb_fp(),
            k: k_fp(),
            v: v_fp(),
        },
        Golden {
            file: "container_v3.bin",
            version: 3,
            key: KvKey::chunk(MODEL, ChunkId(0x5103)),
            has_emb: false,
            n_groups: 1,
            max_quant: QuantLevel::None,
            emb: vec![],
            k: k_fp(),
            v: v_fp(),
        },
        Golden {
            file: "container_v4.bin",
            version: 4,
            key: KvKey::segment(MODEL, &gold_ns, SegmentId::Image(ImageId(0x5104))),
            has_emb: true,
            n_groups: 1,
            max_quant: QuantLevel::None,
            emb: emb_fp(),
            k: k_fp(),
            v: v_fp(),
        },
        Golden {
            file: "container_v5.bin",
            version: 5,
            key: KvKey::image(MODEL, ImageId(0x5105)),
            has_emb: true,
            n_groups: 2,
            max_quant: QuantLevel::None,
            emb: emb_fp(),
            k: k_fp(),
            v: v_fp(),
        },
        Golden {
            file: "container_v6.bin",
            version: 6,
            key: KvKey::segment(MODEL, &gold_ns, SegmentId::Image(ImageId(0x5106))),
            has_emb: true,
            n_groups: 2,
            max_quant: QuantLevel::Int4,
            emb: emb_q(),
            k: k_q(),
            v: v_q(),
        },
    ]
}

/// Every historical container version parses to the right header and
/// decodes to the exact tensors it was written from.
#[test]
fn golden_containers_decode() {
    for g in goldens() {
        let bytes = load(g.file);
        let info = codec::parse_container(&bytes)
            .unwrap_or_else(|e| panic!("{}: parse_container failed: {e:#}", g.file));
        assert_eq!(info.version, g.version, "{}: version", g.file);
        assert_eq!(info.key, g.key, "{}: key", g.file);
        assert_eq!(info.shape, shape(), "{}: shape", g.file);
        assert_eq!(info.has_emb, g.has_emb, "{}: has_emb", g.file);
        assert_eq!(info.n_groups(), g.n_groups, "{}: group count", g.file);
        assert_eq!(info.max_quant(), g.max_quant, "{}: max quant", g.file);

        let e = codec::decode(&bytes)
            .unwrap_or_else(|e| panic!("{}: decode failed: {e:#}", g.file));
        e.validate().unwrap_or_else(|e| panic!("{}: invalid entry: {e:#}", g.file));
        assert_eq!(e.key, g.key, "{}: decoded key", g.file);
        assert_eq!(e.shape, shape(), "{}: decoded shape", g.file);
        assert_eq!(e.emb, g.emb, "{}: emb payload", g.file);
        assert_eq!(e.k, g.k, "{}: k payload", g.file);
        assert_eq!(e.v, g.v, "{}: v payload", g.file);
        println!("OK golden {}", g.file);
    }
}

/// The v6 fixture's group partition: per-group quant levels survive the
/// header round trip, a single group decodes in isolation, and a
/// container *prefix* covering only group 0 stays self-contained — the
/// exact slice `kv.pull` serves for group-range requests.
#[test]
fn golden_v6_groups_and_prefix() {
    let bytes = load("container_v6.bin");
    let info = codec::parse_container(&bytes).expect("parse v6");
    assert_eq!(info.group_quant(0), QuantLevel::Int8);
    assert_eq!(info.group_quant(1), QuantLevel::Int4);
    assert_eq!(info.group_layers(0), (0, 2));
    assert_eq!(info.group_layers(1), (2, 4));

    let g1 = codec::decode_group(&info, &bytes, 1).expect("decode group 1");
    assert!(g1.emb.is_empty(), "only group 0 carries embeddings");
    assert_eq!(g1.k, k_q()[2 * PER_LAYER..], "group 1 k rows");
    assert_eq!(g1.v, v_q()[2 * PER_LAYER..], "group 1 v rows");

    let prefix = &bytes[..info.prefix_len(1)];
    assert!(prefix.len() < bytes.len(), "prefix must drop group 1's chunks");
    let g0 = codec::decode_group(&info, prefix, 0).expect("decode group 0 from prefix");
    assert_eq!(g0.emb, emb_q(), "group 0 emb from prefix");
    assert_eq!(g0.k, k_q()[..2 * PER_LAYER], "group 0 k rows from prefix");
    assert_eq!(g0.v, v_q()[..2 * PER_LAYER], "group 0 v rows from prefix");
    assert!(
        codec::decode_group(&info, prefix, 1).is_err(),
        "group 1 must not decode from a group-0 prefix"
    );
    println!("OK golden v6 groups + prefix");
}

/// Chunk integrity is part of the frozen contract: a flipped payload
/// byte must fail the SHA-256 check, not decode to corrupt tensors.
#[test]
fn golden_corruption_detected() {
    for file in ["container_v1.bin", "container_v2.bin", "container_v6.bin"] {
        let mut bytes = load(file);
        *bytes.last_mut().unwrap() ^= 0xff;
        assert!(codec::decode(&bytes).is_err(), "{file}: corrupted tail must not decode");
    }
    println!("OK golden corruption detection");
}
