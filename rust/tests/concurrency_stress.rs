//! Deterministic concurrency stress over the ranked-lock layer.
//!
//! Every test turns on the debug-build yield injection of
//! `mpic::util::sync` (the in-process equivalent of
//! `MPIC_SYNC_YIELD_SEED`), so each lock acquisition consults a seeded
//! per-thread RNG and occasionally yields — perturbing interleavings
//! into the schedules that historically broke: lease/sweep vs
//! admit/evict across store shards, streamed group scatter racing
//! admits, and dead-peer transport bookkeeping racing metrics
//! snapshots. Debug builds also run the lock-rank checker on every
//! acquisition, so an ordering violation reached by these schedules
//! panics with both acquisition sites instead of deadlocking in the
//! field. The schedule family is a pure function of the seeds set
//! below (plus thread spawn order), so failures replay.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use mpic::cluster::{PeerConfig, PeerTransport};
use mpic::coordinator::metrics::Metrics;
use mpic::kv::store::{KvStore, StoreConfig};
use mpic::kv::{KvKey, KvShape, SegmentKv, TransferEngine, Transport};
use mpic::mm::ImageId;
use mpic::util::rng::Rng;
use mpic::util::sync::set_yield_seed;
use mpic::util::threadpool::ThreadPool;

const SHAPE: KvShape = KvShape { layers: 2, tokens: 4, heads: 2, d_head: 4, d_model: 8 };

fn entry(model: &str, image: u64, seed: u64) -> SegmentKv {
    let mut rng = Rng::new(seed);
    SegmentKv {
        key: KvKey::image(model, ImageId(image)),
        shape: SHAPE,
        emb: (0..SHAPE.emb_elems()).map(|_| rng.normal() as f32).collect(),
        k: (0..SHAPE.kv_elems()).map(|_| rng.normal() as f32).collect(),
        v: (0..SHAPE.kv_elems()).map(|_| rng.normal() as f32).collect(),
    }
}

fn stress_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mpic-stress-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The encoded container of every entry, grabbed up front so admitter
/// threads replay peer-style admits without touching the disk tier.
fn containers(store: &KvStore, entries: &[SegmentKv]) -> Vec<(KvKey, Vec<u8>)> {
    entries
        .iter()
        .map(|e| (e.key.clone(), store.container_bytes(&e.key).expect("put is write-through")))
        .collect()
}

/// Satellite: the lease-sweep path (`LeaseDir` rank) interleaved with
/// shard-side admits, evictions and re-puts on every shard at once.
/// Leases expire mid-test (2ms TTL against a 200ms entry TTL), so the
/// sweeper exercises both lease reaping and disk reaping while the
/// other threads churn residency.
#[test]
fn store_survives_lease_sweep_admit_evict_races() {
    set_yield_seed(Some(0xC0FF_EE00));
    let store = KvStore::new(StoreConfig {
        disk_dir: stress_dir("races"),
        ttl: Duration::from_millis(200),
        shards: 4,
        ..Default::default()
    })
    .unwrap();
    let entries: Vec<SegmentKv> = (0..24).map(|i| entry("stress-races", i, 1000 + i)).collect();
    for e in &entries {
        store.put(e.clone()).unwrap();
    }
    let containers = containers(&store, &entries);
    let n = entries.len();

    std::thread::scope(|s| {
        for t in 0..2 {
            let store = &store;
            let entries = &entries;
            s.spawn(move || {
                for i in 0..150 {
                    let key = &entries[(t * 7 + i) % n].key;
                    if let Some(lease) = store.lease(key, Some(Duration::from_millis(2))) {
                        if i % 3 == 0 {
                            store.lease_release(lease.id);
                        }
                    }
                }
            });
        }
        let store_ref = &store;
        s.spawn(move || {
            for _ in 0..120 {
                store_ref.sweep();
            }
        });
        for t in 0..2 {
            let store = &store;
            let containers = &containers;
            s.spawn(move || {
                for i in 0..100 {
                    let (key, bytes) = &containers[(t * 11 + i) % n];
                    store.admit_container_groups(key, bytes.clone()).unwrap();
                }
            });
        }
        for t in 0..2 {
            let store = &store;
            let entries = &entries;
            s.spawn(move || {
                for i in 0..120 {
                    let e = &entries[(t * 5 + i) % n];
                    store.evict(&e.key);
                    if i % 4 == 0 {
                        store.put(e.clone()).unwrap();
                    }
                }
            });
        }
    });

    store.sweep();
    store.check_invariants().unwrap();
}

/// Satellite regression: streamed fetch (`Transfer#1` queue + scatter
/// workers admitting into `StoreShard`) racing an admit/evict churn on
/// the same keys. The tiny RAM capacities force the disk path, so the
/// stream workers really do admit into shards while the consumer holds
/// the stream-state lock between groups. Every round must still
/// assemble all entries (misses fall back to compute) and leave the
/// store consistent.
#[test]
fn streamed_scatter_races_with_admit_and_evict() {
    set_yield_seed(Some(0xBEEF_BEEF));
    let store = Arc::new(
        KvStore::new(StoreConfig {
            device_capacity: 1,
            host_capacity: 1,
            disk_dir: stress_dir("stream"),
            ttl: Duration::from_secs(60),
            shards: 4,
            ..Default::default()
        })
        .unwrap(),
    );
    let entries: Vec<SegmentKv> = (0..8).map(|i| entry("stress-stream", i, 2000 + i)).collect();
    for e in &entries {
        store.put(e.clone()).unwrap();
    }
    let keys: Vec<KvKey> = entries.iter().map(|e| e.key.clone()).collect();
    let containers = containers(&store, &entries);
    let by_key: HashMap<KvKey, SegmentKv> =
        entries.iter().map(|e| (e.key.clone(), e.clone())).collect();
    let eng = TransferEngine::new(Arc::new(ThreadPool::new(3)));

    std::thread::scope(|s| {
        let store_ref = &store;
        let containers_ref = &containers;
        s.spawn(move || {
            for i in 0..120 {
                let (key, bytes) = &containers_ref[i % containers_ref.len()];
                store_ref.evict(key);
                store_ref.admit_container_groups(key, bytes.clone()).unwrap();
            }
        });
        for t in 0..2 {
            let store = &store;
            let eng = &eng;
            let keys = &keys;
            let by_key = &by_key;
            s.spawn(move || {
                for round in 0..4 {
                    let mut stream = eng.fetch_streamed(store, keys);
                    let mut events = 0usize;
                    while let Some(ev) = stream.next_group() {
                        assert!(ev.slot < keys.len(), "slot out of range: {}", ev.slot);
                        events += 1;
                    }
                    let (got, _report) = stream.finish(|k| Ok(by_key[k].clone())).unwrap();
                    assert_eq!(
                        got.len(),
                        keys.len(),
                        "thread {t} round {round} ({events} stream events)"
                    );
                    for (key, e) in keys.iter().zip(&got) {
                        assert_eq!(&e.key, key);
                    }
                }
            });
        }
    });

    store.check_invariants().unwrap();
}

/// Satellite regression: the transport's dead-peer and negative-probe
/// bookkeeping (`Transfer#2`/`#3`) hammered against metrics snapshots
/// (`Metrics` rank, the highest-but-one), sharing one `ClusterCounters`
/// the way a worker engine wires them. The peer address is a freshly
/// released port, so every call fails fast and drives the mark-dead /
/// retry / revive paths.
#[test]
fn dead_peer_bookkeeping_races_with_metrics_snapshots() {
    set_yield_seed(Some(0xD00D_F00D));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let dead_addr = listener.local_addr().unwrap();
    drop(listener);

    let metrics = Metrics::new();
    let transport =
        PeerTransport::new(vec![dead_addr], PeerConfig::default(), Arc::clone(metrics.cluster()));

    std::thread::scope(|s| {
        for t in 0..3 {
            let transport = &transport;
            s.spawn(move || {
                for i in 0..30 {
                    let key = KvKey::image("stress-net", ImageId((t * 100 + i) as u64));
                    let _ = transport.probe(std::slice::from_ref(&key));
                    let _ = transport.pull(&key);
                }
            });
        }
        for _ in 0..2 {
            let metrics = &metrics;
            s.spawn(move || {
                for _ in 0..60 {
                    let _ = metrics.snapshot();
                }
            });
        }
    });

    let snap = metrics.snapshot();
    assert!(!snap.encode().is_empty());
}
