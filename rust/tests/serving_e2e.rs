//! Serving end-to-end: scheduler (continuous batching) and the TCP server
//! over the real engine + artifacts. Skips when artifacts are not built.

use mpic::coordinator::scheduler::{Request, Scheduler};
use mpic::coordinator::{Engine, EngineConfig, Policy};
use mpic::util::json::Value;
use mpic::workload::{generate, Dataset, WorkloadSpec};

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn test_engine(tag: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("mpic-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Engine::new(EngineConfig {
        model: "mpic-sim-a".into(),
        store: mpic::kv::StoreConfig { disk_dir: dir, ..Default::default() },
        max_new_tokens: 4,
        ..Default::default()
    })
    .expect("engine")
}

#[test]
fn serving_end_to_end() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    scheduler_continuous_batching();
    tcp_server_roundtrip();
}

fn scheduler_continuous_batching() {
    let engine = test_engine("sched");
    let spec = WorkloadSpec {
        dataset: Dataset::Mmdu,
        n_conversations: 4,
        turns_per_conversation: 1,
        images_min: 1,
        images_max: 2,
        seed: 99,
    };
    let convs = generate(&spec);
    for c in &convs {
        for img in &c.images {
            let kv = engine.encode_image(*img).unwrap();
            engine.store().put(kv).unwrap();
        }
    }
    let mut sched = Scheduler::new(2048, 16);
    for (i, c) in convs.iter().enumerate() {
        sched.submit(Request {
            id: i as u64,
            prompt: c.turns[0].clone(),
            policy: Policy::MpicK(16),
            max_new: 4,
        });
    }
    let completions = sched.run_to_completion(&engine).unwrap();
    assert_eq!(completions.len(), 4);
    assert_eq!(sched.stats.completed, 4);
    assert_eq!(sched.stats.rejected, 0);
    // Requests were interleaved: at some point more than one was active.
    assert!(
        sched.stats.max_active > 1,
        "continuous batching should interleave (max_active={})",
        sched.stats.max_active
    );
    // Block pool drained back to empty.
    assert_eq!(sched.block_utilization(), 0.0);
    for c in &completions {
        assert_eq!(c.result.tokens.len(), 4);
    }
    println!(
        "OK scheduler: mean_occupancy={:.2} max_active={}",
        sched.stats.mean_occupancy(),
        sched.stats.max_active
    );
}

fn tcp_server_roundtrip() {
    let engine = test_engine("tcp");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    // Client thread drives the protocol; server runs on this thread
    // (it owns the PJRT handles).
    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = mpic::server::Client::connect(addr).unwrap();

        let pong = c.call(&Value::parse(r#"{"op":"ping"}"#).unwrap()).unwrap();
        assert!(pong.get("ok").unwrap().as_bool().unwrap());

        let up = c
            .call(&Value::parse(r#"{"op":"upload","user":1,"handle":"IMAGE#TCP1"}"#).unwrap())
            .unwrap();
        assert!(up.get("ok").unwrap().as_bool().unwrap(), "{}", up.encode());

        let inf = c
            .call(
                &Value::parse(
                    r#"{"op":"infer","user":1,"policy":"mpic-16","max_new":2,
                        "text":"Describe IMAGE#TCP1 in detail please"}"#,
                )
                .unwrap(),
            )
            .unwrap();
        assert!(inf.get("ok").unwrap().as_bool().unwrap(), "{}", inf.encode());
        assert_eq!(inf.get("steps").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(inf.get("tokens").unwrap().as_arr().unwrap().len(), 2);

        // Malformed input yields an error object, not a hang.
        let bad = c.call(&Value::parse(r#"{"op":"nope"}"#).unwrap()).unwrap();
        assert!(!bad.get("ok").unwrap().as_bool().unwrap());

        // Multi-turn chat keeps session state: turn numbers advance and
        // the second turn reuses the first turn's image from the cache.
        let t1 = c
            .call(
                &Value::parse(
                    r#"{"op":"chat","user":9,"policy":"mpic-16","max_new":2,
                        "text":"Look at IMAGE#TCP1 and describe it"}"#,
                )
                .unwrap(),
            )
            .unwrap();
        assert!(t1.get("ok").unwrap().as_bool().unwrap(), "{}", t1.encode());
        assert_eq!(t1.get("turn").unwrap().as_f64().unwrap(), 1.0);
        let t2 = c
            .call(
                &Value::parse(
                    r#"{"op":"chat","user":9,"policy":"mpic-16","max_new":2,
                        "text":"Now summarise what you said about it"}"#,
                )
                .unwrap(),
            )
            .unwrap();
        assert_eq!(t2.get("turn").unwrap().as_f64().unwrap(), 2.0);
        assert!(
            t2.get("seq_len").unwrap().as_f64().unwrap()
                > t1.get("seq_len").unwrap().as_f64().unwrap(),
            "history must grow"
        );
        assert!(t2.get("device_hits").unwrap().as_f64().unwrap() >= 1.0);
        let reset = c.call(&Value::parse(r#"{"op":"reset","user":9}"#).unwrap()).unwrap();
        assert!(reset.get("ok").unwrap().as_bool().unwrap());

        let stats = c.call(&Value::parse(r#"{"op":"stats"}"#).unwrap()).unwrap();
        let reqs = stats.get("metrics").unwrap().get("requests").unwrap().as_f64().unwrap();
        assert!(reqs >= 1.0);

        let bye = c.call(&Value::parse(r#"{"op":"shutdown"}"#).unwrap()).unwrap();
        assert!(bye.get("ok").unwrap().as_bool().unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server roundtrip");
}
