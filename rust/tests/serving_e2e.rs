//! Serving end-to-end: scheduler (continuous batching) and the TCP server
//! over the real engine + artifacts. Covers the full v2 dispatch surface:
//! v1 backward compatibility, v2 envelopes with request-id echo, structured
//! error codes, cache-management ops, streaming decode, and the online
//! pipeline (concurrent interleaved streams, `overloaded` backpressure,
//! the async upload lane). Skips when artifacts are not built.

use std::io::{BufRead, BufReader, Write};
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use mpic::coordinator::scheduler::{Request, Scheduler};
use mpic::coordinator::{Engine, EngineConfig, Policy};
use mpic::mm::ImageId;
use mpic::server::api::ErrorCode;
use mpic::server::client::WireError;
use mpic::server::pipeline::PipelineConfig;
use mpic::server::{InferOutcome, InferParams, MpicClient, ServeConfig};
use mpic::util::json::Value;
use mpic::workload::{generate, Dataset, WorkloadSpec};

fn artifacts_ready() -> bool {
    let ready = std::path::Path::new("artifacts/manifest.json").exists();
    // CI sets this once it has built the artifacts: a silent skip there
    // would let dispatcher regressions merge behind a green check.
    if !ready && std::env::var("MPIC_REQUIRE_ARTIFACTS").map_or(false, |v| !v.is_empty()) {
        panic!("MPIC_REQUIRE_ARTIFACTS is set but artifacts/manifest.json is missing");
    }
    ready
}

fn test_engine(tag: &str) -> Engine {
    let dir = std::env::temp_dir().join(format!("mpic-serve-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    Engine::new(EngineConfig {
        model: "mpic-sim-a".into(),
        store: mpic::kv::StoreConfig { disk_dir: dir, ..Default::default() },
        max_new_tokens: 4,
        ..Default::default()
    })
    .expect("engine")
}

fn v(s: &str) -> Value {
    Value::parse(s).unwrap()
}

fn assert_ok(resp: &Value) {
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "expected ok: {}", resp.encode());
}

fn assert_code(resp: &Value, code: &str) {
    assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "expected error: {}", resp.encode());
    assert_eq!(resp.get("code").unwrap().as_str().unwrap(), code, "{}", resp.encode());
}

#[test]
fn serving_end_to_end() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    scheduler_continuous_batching();
    tcp_server_v1_compat();
    tcp_server_v2_surface();
    tcp_server_chunk_flow();
    tcp_server_v3_lease_lifecycle();
    tcp_server_namespace_isolation();
    tcp_server_quant_ceiling();
    pipeline_concurrent_streaming();
    pipeline_backpressure_overload();
    pipeline_async_upload_lane();
    pipeline_cancellation();
    client_errors_on_mispaired_replies();
}

fn scheduler_continuous_batching() {
    let engine = test_engine("sched");
    let spec = WorkloadSpec {
        dataset: Dataset::Mmdu,
        n_conversations: 4,
        turns_per_conversation: 1,
        images_min: 1,
        images_max: 2,
        seed: 99,
    };
    let convs = generate(&spec);
    for c in &convs {
        for img in &c.images {
            let kv = engine.encode_image(*img).unwrap();
            engine.store().put(kv).unwrap();
        }
    }
    let mut sched = Scheduler::new(2048, 16);
    for (i, c) in convs.iter().enumerate() {
        sched.submit(Request {
            id: i as u64,
            prompt: c.turns[0].clone(),
            policy: Policy::MpicK(16),
            max_new: 4,
            trace: None,
        });
    }
    let completions = sched.run_to_completion(&engine).unwrap();
    assert_eq!(completions.len(), 4);
    assert_eq!(sched.stats.completed, 4);
    assert_eq!(sched.stats.rejected, 0);
    // Requests were interleaved: at some point more than one was active.
    assert!(
        sched.stats.max_active > 1,
        "continuous batching should interleave (max_active={})",
        sched.stats.max_active
    );
    // Block pool drained back to empty.
    assert_eq!(sched.block_utilization(), 0.0);
    for c in &completions {
        let r = c.result().expect("all requests must be served");
        assert_eq!(r.tokens.len(), 4);
    }
    // Queue-wait accounting: one sample per admitted request.
    assert_eq!(sched.stats.queue_wait.len(), 4);
    assert!(sched.stats.queue_wait_p99() >= sched.stats.queue_wait_p50());
    println!(
        "OK scheduler: mean_occupancy={:.2} max_active={}",
        sched.stats.mean_occupancy(),
        sched.stats.max_active
    );
}

/// Every v1 request shape from the original doc comment must keep working
/// through the v2 dispatcher (backward compatibility).
fn tcp_server_v1_compat() {
    let engine = test_engine("tcp");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    // Client thread drives the protocol; server runs on this thread
    // (it owns the PJRT handles).
    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = mpic::server::Client::connect(addr).unwrap();

        let pong = c.call(&v(r#"{"op":"ping"}"#)).unwrap();
        assert_ok(&pong);

        let up = c.call(&v(r#"{"op":"upload","user":1,"handle":"IMAGE#TCP1"}"#)).unwrap();
        assert_ok(&up);

        let add = c
            .call(&v(r#"{"op":"add_reference","handle":"IMAGE#REF1","description":"a reference"}"#))
            .unwrap();
        assert_ok(&add);

        let inf = c
            .call(&v(
                r#"{"op":"infer","user":1,"policy":"mpic-16","max_new":2,
                    "text":"Describe IMAGE#TCP1 in detail please"}"#,
            ))
            .unwrap();
        assert_ok(&inf);
        assert_eq!(inf.get("steps").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(inf.get("tokens").unwrap().as_arr().unwrap().len(), 2);

        // Malformed input yields a coded error object, not a hang.
        let bad = c.call(&v(r#"{"op":"nope"}"#)).unwrap();
        assert_code(&bad, "unknown_op");

        // Multi-turn chat keeps session state: turn numbers advance and
        // the second turn reuses the first turn's image from the cache.
        let t1 = c
            .call(&v(
                r#"{"op":"chat","user":9,"policy":"mpic-16","max_new":2,
                    "text":"Look at IMAGE#TCP1 and describe it"}"#,
            ))
            .unwrap();
        assert_ok(&t1);
        assert_eq!(t1.get("turn").unwrap().as_f64().unwrap(), 1.0);
        let t2 = c
            .call(&v(
                r#"{"op":"chat","user":9,"policy":"mpic-16","max_new":2,
                    "text":"Now summarise what you said about it"}"#,
            ))
            .unwrap();
        assert_eq!(t2.get("turn").unwrap().as_f64().unwrap(), 2.0);
        assert!(
            t2.get("seq_len").unwrap().as_f64().unwrap()
                > t1.get("seq_len").unwrap().as_f64().unwrap(),
            "history must grow"
        );
        assert!(t2.get("device_hits").unwrap().as_f64().unwrap() >= 1.0);
        let reset = c.call(&v(r#"{"op":"reset","user":9}"#)).unwrap();
        assert_ok(&reset);

        let stats = c.call(&v(r#"{"op":"stats"}"#)).unwrap();
        let reqs = stats.get("metrics").unwrap().get("requests").unwrap().as_f64().unwrap();
        assert!(reqs >= 1.0);

        let bye = c.call(&v(r#"{"op":"shutdown"}"#)).unwrap();
        assert_ok(&bye);
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server v1 compat");
}

/// The v2 surface: envelopes + id echo, error-code paths, the
/// cache.list → cache.pin → cache.evict → cache.stat sequence, session
/// introspection and a streaming infer round-trip — all over real TCP.
fn tcp_server_v2_surface() {
    let engine = test_engine("tcpv2");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = mpic::server::Client::connect(addr).unwrap();

        // ---- v2 envelope: version + request-id echo (string and number).
        let pong = c.call(&v(r#"{"v":2,"id":"r1","op":"ping"}"#)).unwrap();
        assert_ok(&pong);
        assert_eq!(pong.get("id").unwrap().as_str().unwrap(), "r1");

        let up = c.call(&v(r#"{"v":2,"id":7,"op":"upload","user":1,"handle":"IMAGE#V2A"}"#)).unwrap();
        assert_ok(&up);
        assert_eq!(up.get("id").unwrap().as_f64().unwrap(), 7.0);
        let hex = format!("{:016x}", ImageId::from_handle("IMAGE#V2A").0);
        assert_eq!(up.get("image_hex").unwrap().as_str().unwrap(), hex);

        // ---- error-code paths.
        assert_code(&c.call(&v(r#"{"v":2,"op":"nope"}"#)).unwrap(), "unknown_op");
        assert_code(&c.call(&v(r#"{"v":2,"op":"upload","user":1}"#)).unwrap(), "missing_field");
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"upload","user":"one","handle":"h"}"#)).unwrap(),
            "bad_type",
        );
        assert_code(&c.call(&v(r#"{"v":9,"op":"ping"}"#)).unwrap(), "bad_version");
        // v3 is the current protocol version.
        assert_ok(&c.call(&v(r#"{"v":3,"op":"ping"}"#)).unwrap());
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"infer","user":1,"text":"hi there friend","policy":"bogus"}"#))
                .unwrap(),
            "bad_value",
        );
        // Errors still echo the id so pipelined clients can correlate.
        let e = c.call(&v(r#"{"v":2,"id":"bad-1","op":"nope"}"#)).unwrap();
        assert_code(&e, "unknown_op");
        assert_eq!(e.get("id").unwrap().as_str().unwrap(), "bad-1");

        // Raw non-JSON input gets a bad_json error on a second connection.
        let mut raw = std::net::TcpStream::connect(addr).unwrap();
        raw.write_all(b"this is not json\n").unwrap();
        raw.flush().unwrap();
        let mut line = String::new();
        BufReader::new(&raw).read_line(&mut line).unwrap();
        assert_code(&Value::parse(line.trim_end()).unwrap(), "bad_json");
        drop(raw);

        // ---- cache management: list → stat → pin → evict(refused) →
        //      unpin → evict → stat(not_found).
        let list = c.call(&v(r#"{"v":2,"op":"cache.list"}"#)).unwrap();
        assert_ok(&list);
        assert!(list.get("count").unwrap().as_usize().unwrap() >= 1);
        let entries = list.get("entries").unwrap().as_arr().unwrap();
        let mine = entries
            .iter()
            .find(|e| e.get("image").unwrap().as_str().unwrap() == hex)
            .expect("uploaded image must be listed");
        assert_eq!(mine.get("tier").unwrap().as_str().unwrap(), "device");
        assert!(!mine.get("pinned").unwrap().as_bool().unwrap());
        assert!(mine.get("bytes").unwrap().as_usize().unwrap() > 0);

        let stat = c.call(&v(r#"{"v":2,"op":"cache.stat","handle":"IMAGE#V2A"}"#)).unwrap();
        assert_ok(&stat);
        assert!(stat.get("resident").unwrap().as_bool().unwrap());
        assert_eq!(stat.get("tier").unwrap().as_str().unwrap(), "device");

        let pin = c.call(&v(r#"{"v":2,"op":"cache.pin","handle":"IMAGE#V2A"}"#)).unwrap();
        assert_ok(&pin);
        assert!(pin.get("pinned").unwrap().as_bool().unwrap());

        // Pinned entries refuse eviction with a dedicated code.
        let refused = c.call(&v(r#"{"v":2,"op":"cache.evict","handle":"IMAGE#V2A"}"#)).unwrap();
        assert_code(&refused, "pinned");
        let still = c.call(&v(r#"{"v":2,"op":"cache.stat","handle":"IMAGE#V2A"}"#)).unwrap();
        assert_ok(&still);
        assert!(still.get("pinned").unwrap().as_bool().unwrap());

        let unpin =
            c.call(&v(r#"{"v":2,"op":"cache.pin","handle":"IMAGE#V2A","pinned":false}"#)).unwrap();
        assert_ok(&unpin);
        let evicted = c.call(&v(r#"{"v":2,"op":"cache.evict","handle":"IMAGE#V2A"}"#)).unwrap();
        assert_ok(&evicted);
        assert!(evicted.get("evicted").unwrap().as_bool().unwrap());
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"cache.stat","handle":"IMAGE#V2A"}"#)).unwrap(),
            "not_found",
        );
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"cache.evict","handle":"IMAGE#V2A"}"#)).unwrap(),
            "not_found",
        );
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"cache.pin","handle":"IMAGE#NEVER"}"#)).unwrap(),
            "not_found",
        );

        // Re-upload for the streaming stage below.
        assert_ok(&c.call(&v(r#"{"v":2,"op":"upload","user":1,"handle":"IMAGE#V2A"}"#)).unwrap());

        // ---- streaming decode: one chunk line per token, ordered seqs,
        //      id echo on every line, then a done summary.
        let mut chunks = Vec::new();
        let fin = c
            .call_stream(
                &v(
                    r#"{"v":2,"id":"s1","op":"infer","user":1,"policy":"mpic-16","max_new":3,
                        "stream":true,"text":"Describe IMAGE#V2A in detail please"}"#,
                ),
                |chunk| chunks.push(chunk.clone()),
            )
            .unwrap();
        assert_ok(&fin);
        assert!(fin.get("done").unwrap().as_bool().unwrap());
        assert_eq!(fin.get("id").unwrap().as_str().unwrap(), "s1");
        let tokens = fin.get("tokens").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(tokens.len(), 3);
        assert_eq!(chunks.len(), 3, "one chunk per decoded token");
        for (i, chunk) in chunks.iter().enumerate() {
            assert_ok(chunk);
            assert!(chunk.get("stream").unwrap().as_bool().unwrap());
            assert_eq!(chunk.get("seq").unwrap().as_usize().unwrap(), i);
            assert_eq!(chunk.get("id").unwrap().as_str().unwrap(), "s1");
            assert_eq!(
                chunk.get("token").unwrap().as_f64().unwrap(),
                tokens[i].as_f64().unwrap(),
                "chunk tokens must match the final summary"
            );
        }

        // ---- streaming chat + session introspection.
        let mut chat_chunks = 0usize;
        let cfin = c
            .call_stream(
                &v(
                    r#"{"v":2,"id":"s2","op":"chat","user":42,"policy":"mpic-16","max_new":2,
                        "stream":true,"text":"Look at IMAGE#V2A please"}"#,
                ),
                |_| chat_chunks += 1,
            )
            .unwrap();
        assert_ok(&cfin);
        assert_eq!(cfin.get("turn").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(chat_chunks, 2);

        let sl = c.call(&v(r#"{"v":2,"op":"session.list"}"#)).unwrap();
        assert_ok(&sl);
        assert_eq!(sl.get("count").unwrap().as_usize().unwrap(), 1);
        let sess = &sl.get("sessions").unwrap().as_arr().unwrap()[0];
        assert_eq!(sess.get("user").unwrap().as_f64().unwrap(), 42.0);
        assert_eq!(sess.get("turns").unwrap().as_f64().unwrap(), 1.0);
        assert!(sess.get("images").unwrap().as_usize().unwrap() >= 1);

        let ss = c.call(&v(r#"{"v":2,"op":"session.stat","user":42}"#)).unwrap();
        assert_ok(&ss);
        assert!(ss.get("history_len").unwrap().as_usize().unwrap() >= 2);
        assert_code(&c.call(&v(r#"{"v":2,"op":"session.stat","user":4242}"#)).unwrap(), "not_found");
        assert_ok(&c.call(&v(r#"{"v":2,"op":"reset","user":42}"#)).unwrap());
        let sl2 = c.call(&v(r#"{"v":2,"op":"session.list"}"#)).unwrap();
        assert_eq!(sl2.get("count").unwrap().as_usize().unwrap(), 0);

        // ---- stats carries the per-op counter/latency table.
        let stats = c.call(&v(r#"{"v":2,"op":"stats"}"#)).unwrap();
        assert_ok(&stats);
        let ops = stats.get("metrics").unwrap().get("ops").unwrap();
        assert!(ops.get("infer").unwrap().get("n").unwrap().as_f64().unwrap() >= 1.0);
        assert!(ops.get("cache.pin").unwrap().get("n").unwrap().as_f64().unwrap() >= 2.0);
        assert!(ops.get("cache.pin").unwrap().get("mean").unwrap().as_f64().unwrap() >= 0.0);
        // Unknown op names must not leak into the table verbatim (they
        // would grow it without bound); they share one "unknown" bucket.
        assert!(ops.get("nope").is_err());
        assert!(ops.get("unknown").unwrap().get("n").unwrap().as_f64().unwrap() >= 2.0);
        assert!(stats.get("store").unwrap().get("device_bytes").unwrap().as_f64().unwrap() > 0.0);
        assert!(stats.get("store").unwrap().get("shards").unwrap().as_f64().unwrap() >= 1.0);
        // The KV hot-path counters ride along under metrics.kv; an earlier
        // upload in this test guarantees codec work was recorded.
        let kv = stats.get("metrics").unwrap().get("kv").unwrap();
        assert!(kv.get("codec_chunks").unwrap().as_f64().unwrap() >= 1.0);
        for field in ["lock_contention", "prefetch_issued", "prefetch_hits", "prefetch_wasted"] {
            assert!(kv.get(field).unwrap().as_f64().unwrap() >= 0.0, "missing kv.{field}");
        }

        // A rejected shutdown (bad envelope) must not kill the server.
        assert_code(&c.call(&v(r#"{"v":9,"op":"shutdown"}"#)).unwrap(), "bad_version");
        assert_ok(&c.call(&v(r#"{"v":2,"op":"ping"}"#)).unwrap());

        assert_ok(&c.call(&v(r#"{"v":2,"id":"bye","op":"shutdown"}"#)).unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server v2 surface");
}

/// The chunk flow over the wire: `chunk.upload`, `CHUNK#` references in
/// `infer` text, cache management on the chunk entry, and the unknown-
/// chunk error path.
fn tcp_server_chunk_flow() {
    let engine = test_engine("tcpchunk");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = mpic::server::Client::connect(addr).unwrap();

        // Upload a chunk (plain) and one that is also MRAG-indexed.
        let up = c
            .call(&v(
                r#"{"v":2,"id":"c1","op":"chunk.upload","handle":"CHUNK#TCPDOC",
                    "text":"the shared harbour festival report with boats and stalls"}"#,
            ))
            .unwrap();
        assert_ok(&up);
        assert!(up.get("tokens").unwrap().as_usize().unwrap() >= 5);
        assert!(!up.get("indexed").unwrap().as_bool().unwrap());
        let indexed = c
            .call(&v(
                r#"{"v":2,"op":"chunk.upload","handle":"CHUNK#TCPDOC2",
                    "text":"a guidebook chapter on riverside walks",
                    "description":"riverside walks guidebook"}"#,
            ))
            .unwrap();
        assert_ok(&indexed);
        assert!(indexed.get("indexed").unwrap().as_bool().unwrap());

        // Bad handles are rejected with bad_value.
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"chunk.upload","handle":"IMAGE#X","text":"t"}"#)).unwrap(),
            "bad_value",
        );

        // Two infers with different openers, same chunk: both must be
        // served with zero misses (device_hits >= 1 ⇒ the chunk KV came
        // from the store, never re-prefilled).
        for (id, text) in [
            ("i1", "Summarise briefly: CHUNK#TCPDOC please"),
            ("i2", "A totally different opener — what does CHUNK#TCPDOC say"),
        ] {
            let inf = c
                .call(&v(&format!(
                    r#"{{"v":2,"id":"{id}","op":"infer","user":1,"policy":"mpic-8",
                        "max_new":2,"text":"{text}"}}"#
                )))
                .unwrap();
            assert_ok(&inf);
            assert!(inf.get("device_hits").unwrap().as_f64().unwrap() >= 1.0);
        }

        // The chunk entry is manageable through the cache API and reports
        // its kind.
        let stat = c.call(&v(r#"{"v":2,"op":"cache.stat","handle":"CHUNK#TCPDOC"}"#)).unwrap();
        assert_ok(&stat);
        assert_eq!(stat.get("kind").unwrap().as_str().unwrap(), "chunk");
        assert!(stat.get("resident").unwrap().as_bool().unwrap());
        assert_ok(&c.call(&v(r#"{"v":2,"op":"cache.pin","handle":"CHUNK#TCPDOC"}"#)).unwrap());
        assert_code(
            &c.call(&v(r#"{"v":2,"op":"cache.evict","handle":"CHUNK#TCPDOC"}"#)).unwrap(),
            "pinned",
        );
        assert_ok(
            &c.call(&v(r#"{"v":2,"op":"cache.pin","handle":"CHUNK#TCPDOC","pinned":false}"#))
                .unwrap(),
        );

        // Referencing a never-uploaded chunk is a clean error, not a hang.
        let missing = c
            .call(&v(
                r#"{"v":2,"op":"infer","user":1,"max_new":2,"text":"explain CHUNK#NOSUCH now"}"#,
            ))
            .unwrap();
        assert!(!missing.get("ok").unwrap().as_bool().unwrap());

        assert_ok(&c.call(&v(r#"{"v":2,"op":"shutdown"}"#)).unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server chunk flow");
}

/// N concurrent clients issue streaming `infer`s: every id must be
/// answered with a full token stream, and chunks of different requests
/// must interleave (continuous batching on the wire), not serialise.
fn pipeline_concurrent_streaming() {
    const N: usize = 3;
    const MAX_NEW: usize = 8;
    let engine = test_engine("pipe");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let driver = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut admin = mpic::server::Client::connect(addr).unwrap();
        assert_ok(&admin.call(&v(r#"{"op":"upload","user":1,"handle":"IMAGE#PIPE"}"#)).unwrap());

        // Global chunk-arrival timeline: (client, seq) in receive order.
        let timeline: Arc<Mutex<Vec<(usize, usize)>>> = Arc::new(Mutex::new(Vec::new()));
        let barrier = Arc::new(Barrier::new(N));
        let mut clients = Vec::new();
        for ci in 0..N {
            let timeline = Arc::clone(&timeline);
            let barrier = Arc::clone(&barrier);
            clients.push(std::thread::spawn(move || {
                let mut c = mpic::server::Client::connect(addr).unwrap();
                barrier.wait();
                let req = Value::parse(&format!(
                    r#"{{"v":2,"id":"c{ci}","op":"infer","user":1,"policy":"mpic-16","max_new":{MAX_NEW},"stream":true,"text":"Describe IMAGE#PIPE in detail please"}}"#
                ))
                .unwrap();
                let fin = c
                    .call_stream(&req, |chunk| {
                        let seq = chunk.get("seq").unwrap().as_usize().unwrap();
                        timeline.lock().unwrap().push((ci, seq));
                    })
                    .unwrap();
                // (b) every id answered, in full.
                assert_ok(&fin);
                assert!(fin.get("done").unwrap().as_bool().unwrap());
                assert_eq!(fin.get("id").unwrap().as_str().unwrap(), format!("c{ci}"));
                assert_eq!(fin.get("tokens").unwrap().as_arr().unwrap().len(), MAX_NEW);
                assert!(fin.opt("queued_rounds").is_some());
            }));
        }
        for h in clients {
            h.join().unwrap();
        }

        let tl = timeline.lock().unwrap();
        assert_eq!(tl.len(), N * MAX_NEW, "every chunk of every stream must arrive");
        // Per-client seqs are ordered.
        for ci in 0..N {
            let seqs: Vec<usize> = tl.iter().filter(|(c, _)| *c == ci).map(|&(_, s)| s).collect();
            assert_eq!(seqs, (0..MAX_NEW).collect::<Vec<_>>(), "client {ci} chunks ordered");
        }
        // (a) interleaving: strictly serialised streams would show exactly
        // N-1 client switches in the timeline; round-robin decode shows
        // many more. Require at least one mid-stream switch.
        let switches = tl.windows(2).filter(|w| w[0].0 != w[1].0).count();
        assert!(
            switches > N - 1,
            "streams must interleave, not serialise (switches={switches}, timeline={tl:?})"
        );
        drop(tl);

        // Pipeline health surfaced in stats.
        let stats = admin.call(&v(r#"{"v":2,"op":"stats"}"#)).unwrap();
        let pipe = stats.get("metrics").unwrap().get("pipeline").unwrap();
        assert!(
            pipe.get("batch_occupancy").unwrap().get("mean").unwrap().as_f64().unwrap() > 1.0,
            "decode rounds must have interleaved >1 sequence: {}",
            pipe.encode()
        );
        assert!(pipe.get("admission_wait_s").unwrap().get("n").unwrap().as_f64().unwrap() >= 3.0);

        assert_ok(&admin.call(&v(r#"{"op":"shutdown"}"#)).unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    driver.join().unwrap();
    println!("OK pipeline concurrent streaming");
}

/// With queue_bound=1, a second generation arriving while one streams must
/// be rejected `overloaded` (not queued, not hung); once the stream
/// finishes, a retry succeeds.
fn pipeline_backpressure_overload() {
    let engine = test_engine("bp");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let driver = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut admin = mpic::server::Client::connect(addr).unwrap();
        assert_ok(&admin.call(&v(r#"{"op":"upload","user":1,"handle":"IMAGE#BP"}"#)).unwrap());

        // Client A holds the only in-flight slot with a long stream.
        let (started_tx, started_rx) = std::sync::mpsc::channel();
        let a = std::thread::spawn(move || {
            let mut c = mpic::server::Client::connect(addr).unwrap();
            let mut signalled = false;
            let fin = c
                .call_stream(
                    &v(
                        r#"{"v":2,"id":"long","op":"infer","user":1,"policy":"mpic-16","max_new":12,"stream":true,"text":"Describe IMAGE#BP in detail please"}"#,
                    ),
                    |_| {
                        if !signalled {
                            started_tx.send(()).unwrap();
                            signalled = true;
                        }
                    },
                )
                .unwrap();
            assert_ok(&fin);
            fin
        });
        started_rx.recv().unwrap(); // A is mid-stream: slot occupied.

        // (c) the queue bound is exceeded: reject with `overloaded`.
        let rejected = admin
            .call(&v(
                r#"{"v":2,"id":"r","op":"infer","user":1,"policy":"mpic-16","max_new":2,"text":"Describe IMAGE#BP please"}"#,
            ))
            .unwrap();
        assert_code(&rejected, "overloaded");
        assert_eq!(rejected.get("id").unwrap().as_str().unwrap(), "r");

        // Control ops stay serviceable while the lane is saturated.
        assert_ok(&admin.call(&v(r#"{"v":2,"op":"ping"}"#)).unwrap());
        assert_ok(&admin.call(&v(r#"{"v":2,"op":"cache.list"}"#)).unwrap());

        let fin = a.join().unwrap();
        assert_eq!(fin.get("tokens").unwrap().as_arr().unwrap().len(), 12);

        // Slot free again: the retry is admitted and served.
        let ok = admin
            .call(&v(
                r#"{"v":2,"op":"infer","user":1,"policy":"mpic-16","max_new":2,"text":"Describe IMAGE#BP please"}"#,
            ))
            .unwrap();
        assert_ok(&ok);

        // The reject is visible in pipeline health.
        let stats = admin.call(&v(r#"{"v":2,"op":"stats"}"#)).unwrap();
        let pipe = stats.get("metrics").unwrap().get("pipeline").unwrap();
        assert!(pipe.get("rejected_overloaded").unwrap().as_f64().unwrap() >= 1.0);

        assert_ok(&admin.call(&v(r#"{"op":"shutdown"}"#)).unwrap());
    });

    let cfg = ServeConfig {
        pipeline: PipelineConfig { queue_bound: 1, ..Default::default() },
        ..Default::default()
    };
    mpic::server::serve_with(&engine, "127.0.0.1:0", cfg, |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    driver.join().unwrap();
    println!("OK pipeline backpressure overload");
}

/// The async upload lane: accept-with-job-id, `upload.stat` polling to
/// `done`, `jobs.list`, and the uploaded image being usable for inference.
fn pipeline_async_upload_lane() {
    let engine = test_engine("upl");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let driver = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = mpic::server::Client::connect(addr).unwrap();

        let acc = c
            .call(&v(r#"{"v":2,"id":"u1","op":"upload","user":1,"handle":"IMAGE#ASY","async":true}"#))
            .unwrap();
        assert_ok(&acc);
        assert!(acc.get("accepted").unwrap().as_bool().unwrap());
        assert_eq!(acc.get("id").unwrap().as_str().unwrap(), "u1");
        let jid = acc.get("job").unwrap().as_u64().unwrap();

        // Poll to completion.
        let hex = format!("{:016x}", ImageId::from_handle("IMAGE#ASY").0);
        let mut state = String::new();
        for _ in 0..500 {
            let st = c
                .call(&Value::parse(&format!(r#"{{"v":2,"op":"upload.stat","job":{jid}}}"#)).unwrap())
                .unwrap();
            assert_ok(&st);
            state = st.get("state").unwrap().as_str().unwrap().to_string();
            assert_ne!(state, "failed", "async upload failed: {}", st.encode());
            if state == "done" {
                assert_eq!(st.get("image_hex").unwrap().as_str().unwrap(), hex);
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(state, "done", "upload job must complete");

        // The KV is resident and the handle registered: infer works.
        let stat = c.call(&v(r#"{"v":2,"op":"cache.stat","handle":"IMAGE#ASY"}"#)).unwrap();
        assert_ok(&stat);
        assert!(stat.get("resident").unwrap().as_bool().unwrap());
        let inf = c
            .call(&v(
                r#"{"v":2,"op":"infer","user":1,"policy":"mpic-16","max_new":2,"text":"Describe IMAGE#ASY please"}"#,
            ))
            .unwrap();
        assert_ok(&inf);
        assert!(inf.get("device_hits").unwrap().as_f64().unwrap() >= 1.0);

        // add_reference rides the same lane.
        let acc2 = c
            .call(&v(
                r#"{"v":2,"op":"add_reference","handle":"IMAGE#ASYREF","description":"a reference","async":true}"#,
            ))
            .unwrap();
        assert_ok(&acc2);

        // Job table introspection + error paths.
        let jl = c.call(&v(r#"{"v":2,"op":"jobs.list"}"#)).unwrap();
        assert_ok(&jl);
        assert!(jl.get("count").unwrap().as_usize().unwrap() >= 2);
        assert_code(&c.call(&v(r#"{"v":2,"op":"upload.stat","job":999999}"#)).unwrap(), "not_found");
        assert_code(&c.call(&v(r#"{"v":2,"op":"upload.stat"}"#)).unwrap(), "missing_field");

        // Async uploads counted in pipeline health.
        let mut counted = 0.0;
        for _ in 0..500 {
            let stats = c.call(&v(r#"{"v":2,"op":"stats"}"#)).unwrap();
            counted = stats
                .get("metrics")
                .unwrap()
                .get("pipeline")
                .unwrap()
                .get("async_uploads")
                .unwrap()
                .as_f64()
                .unwrap();
            if counted >= 2.0 {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(counted >= 2.0, "async upload completions must surface in stats ({counted})");

        assert_ok(&c.call(&v(r#"{"op":"shutdown"}"#)).unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    driver.join().unwrap();
    println!("OK pipeline async upload lane");
}

/// Expect a typed-client error carrying the given wire code.
fn assert_wire_code(r: mpic::Result<impl std::fmt::Debug>, code: ErrorCode) {
    match r {
        Ok(v) => panic!("expected {code:?} error, got success: {v:?}"),
        Err(e) => match e.downcast_ref::<WireError>() {
            Some(w) => assert_eq!(w.code, code, "wrong wire code: {w}"),
            None => panic!("expected a WireError, got: {e:#}"),
        },
    }
}

/// The v3 lease lifecycle over live TCP through the typed client: a
/// leased entry refuses eviction, renewal extends past the original TTL,
/// release (and expiry) make it evictable, an expired lease cannot be
/// revived, and the v2 pin path still behaves as before.
fn tcp_server_v3_lease_lifecycle() {
    let engine = test_engine("lease");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = MpicClient::connect(addr).unwrap();
        c.upload(1, "IMAGE#LEASE").unwrap();

        // Grant → introspect → evict refused.
        let lease = c.lease("IMAGE#LEASE", Some(250)).unwrap();
        let stat = c.cache_stat("IMAGE#LEASE").unwrap();
        assert!(stat.pinned, "a live lease reads as pinned");
        assert_eq!(stat.leases, 1);
        assert_wire_code(c.cache_evict("IMAGE#LEASE"), ErrorCode::Pinned);

        // Renew well past the original 250ms: protection must hold.
        let lease = c.lease_renew(&lease, Some(30_000)).unwrap();
        std::thread::sleep(Duration::from_millis(400));
        assert_wire_code(c.cache_evict("IMAGE#LEASE"), ErrorCode::Pinned);

        // Release → ordinary citizen again.
        c.lease_release(&lease).unwrap();
        assert_wire_code(c.lease_release(&lease), ErrorCode::NotFound);
        c.cache_evict("IMAGE#LEASE").unwrap();

        // Expiry: a short lease lapses on its own; the entry becomes
        // evictable and the lease cannot be revived.
        c.upload(1, "IMAGE#LEASE").unwrap();
        let short = c.lease("IMAGE#LEASE", Some(80)).unwrap();
        assert_wire_code(c.cache_evict("IMAGE#LEASE"), ErrorCode::Pinned);
        std::thread::sleep(Duration::from_millis(200));
        assert_wire_code(c.lease_renew(&short, Some(30_000)), ErrorCode::NotFound);
        c.cache_evict("IMAGE#LEASE").unwrap();

        // Leasing something that is not resident is not_found.
        assert_wire_code(c.lease("IMAGE#NEVER", None), ErrorCode::NotFound);

        // v2 pin compat rides the same machinery: pin = one infinite
        // lease, visible in the lease count, released by unpin.
        c.upload(1, "IMAGE#LEASE").unwrap();
        c.cache_pin("IMAGE#LEASE", true).unwrap();
        c.cache_pin("IMAGE#LEASE", true).unwrap(); // idempotent
        let stat = c.cache_stat("IMAGE#LEASE").unwrap();
        assert!(stat.pinned);
        assert_eq!(stat.leases, 1, "double pin holds one compat lease");
        assert_wire_code(c.cache_evict("IMAGE#LEASE"), ErrorCode::Pinned);
        c.cache_pin("IMAGE#LEASE", false).unwrap();
        c.cache_evict("IMAGE#LEASE").unwrap();

        // Lease traffic surfaces in the kv metrics.
        let stats = c.stats().unwrap();
        let kv = stats.get("metrics").unwrap().get("kv").unwrap();
        assert!(kv.get("leases_acquired").unwrap().as_f64().unwrap() >= 3.0);
        assert!(kv.get("leases_released").unwrap().as_f64().unwrap() >= 2.0);

        c.shutdown().unwrap();
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server v3 lease lifecycle");
}

/// Two tenants upload the same handles: distinct cache entries, scoped
/// listings, no cross-tenant resolution — and the default namespace sees
/// none of it.
fn tcp_server_namespace_isolation() {
    let engine = test_engine("ns");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut a = MpicClient::connect(addr).unwrap().with_namespace("tenant-a").unwrap();
        let mut b = MpicClient::connect(addr).unwrap().with_namespace("tenant-b").unwrap();
        let mut root = MpicClient::connect(addr).unwrap();

        // Same handles, different tenants (and different chunk contents).
        a.upload(1, "IMAGE#LOGO").unwrap();
        b.upload(1, "IMAGE#LOGO").unwrap();
        let (_, a_tokens) = a
            .chunk_upload("CHUNK#SHARED", "tenant a's private report about the harbour", None)
            .unwrap();
        let b_text = "tenant b's much longer confidential document about \
                      the riverside warehouses and their inventory";
        let (_, b_tokens) = b.chunk_upload("CHUNK#SHARED", b_text, None).unwrap();
        assert!(b_tokens > a_tokens, "each tenant's CHUNK#SHARED holds its own text");

        // Listings are tenant-scoped; the root namespace sees nothing.
        for (name, c) in [("tenant-a", &mut a), ("tenant-b", &mut b)] {
            let entries = c.cache_list().unwrap();
            assert_eq!(entries.len(), 2, "{name} sees exactly its own entries");
            for e in &entries {
                assert_eq!(e.ns.as_deref(), Some(name), "entry ns must match the caller");
            }
        }
        assert!(root.cache_list().unwrap().is_empty(), "default ns must see no tenant entries");
        assert_wire_code(root.cache_stat("IMAGE#LOGO"), ErrorCode::NotFound);

        // Both tenants' inferences hit their own cached segments.
        for c in [&mut a, &mut b] {
            let r = c
                .infer(
                    &InferParams::new(1, "Summarise CHUNK#SHARED next to IMAGE#LOGO please")
                        .policy("mpic-8")
                        .max_new(2),
                )
                .unwrap();
            assert_eq!(r.tokens.len(), 2);
            assert!(r.device_hits >= 1, "tenant segments must come from the cache");
        }

        // Leases are tenant-owned: B cannot release or renew (i.e.
        // un-protect) A's lease even though ids are guessable.
        let a_lease = a.lease("IMAGE#LOGO", Some(60_000)).unwrap();
        let stolen = mpic::server::Lease { id: a_lease.id, handle: String::new(), ttl_ms: None };
        assert_wire_code(b.lease_release(&stolen), ErrorCode::NotFound);
        assert_wire_code(b.lease_renew(&stolen, Some(1)), ErrorCode::NotFound);
        assert_eq!(a.cache_stat("IMAGE#LOGO").unwrap().leases, 1, "A's lease must survive");
        a.lease_release(&a_lease).unwrap();

        // A handle only tenant A uploaded does not resolve for tenant B.
        a.chunk_upload("CHUNK#ONLYA", "a secret addendum", None).unwrap();
        assert_wire_code(b.cache_stat("CHUNK#ONLYA"), ErrorCode::NotFound);
        let missing =
            b.infer(&InferParams::new(1, "explain CHUNK#ONLYA now").policy("mpic-8").max_new(2));
        assert!(missing.is_err(), "cross-tenant chunk reference must fail");

        // The store really holds one entry per (tenant, handle): 2 images
        // + 2 shared chunks + 1 addendum = 5 disk entries.
        let stats = root.stats().unwrap();
        let disk = stats.get("store").unwrap().get("disk_entries").unwrap().as_f64().unwrap();
        assert!(disk >= 5.0, "expected >=5 namespaced entries, got {disk}");

        // Sessions are per-tenant too: same user id, independent turns.
        a.chat(&InferParams::new(9, "Look at IMAGE#LOGO").policy("mpic-8").max_new(2)).unwrap();
        let sa = a.call_raw(&v(r#"{"v":3,"ns":"tenant-a","op":"session.stat","user":9}"#), |_| {})
            .unwrap();
        assert_ok(&sa);
        assert_eq!(sa.get("turns").unwrap().as_f64().unwrap(), 1.0);
        let sb = b.call_raw(&v(r#"{"v":3,"ns":"tenant-b","op":"session.stat","user":9}"#), |_| {})
            .unwrap();
        assert_code(&sb, "not_found");

        root.shutdown().unwrap();
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server namespace isolation");
}

/// The per-tenant compression ceiling over the wire: `cache.quant` reads
/// back the namespace default, a set is scoped to the caller's tenant,
/// `"none"` opts a tenant out of compression entirely, and a bogus level
/// is a `bad_value` — never a silent fallback.
fn tcp_server_quant_ceiling() {
    let engine = test_engine("quant");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut c = mpic::server::Client::connect(addr).unwrap();

        // A bare read reports the default ceiling: int4, i.e. any
        // configured tier floor applies unrestricted.
        let cur = c.call(&v(r#"{"v":3,"op":"cache.quant"}"#)).unwrap();
        assert_ok(&cur);
        assert_eq!(cur.get("level").unwrap().as_str().unwrap(), "int4");

        // Tighten tenant-q to int8; the write echoes the new ceiling and
        // a follow-up read agrees.
        let set = c
            .call(&v(r#"{"v":3,"ns":"tenant-q","op":"cache.quant","level":"int8"}"#))
            .unwrap();
        assert_ok(&set);
        assert_eq!(set.get("level").unwrap().as_str().unwrap(), "int8");
        let back = c.call(&v(r#"{"v":3,"ns":"tenant-q","op":"cache.quant"}"#)).unwrap();
        assert_eq!(back.get("level").unwrap().as_str().unwrap(), "int8");

        // The ceiling is tenant-scoped: other namespaces keep the default.
        let other = c.call(&v(r#"{"v":3,"ns":"tenant-r","op":"cache.quant"}"#)).unwrap();
        assert_eq!(other.get("level").unwrap().as_str().unwrap(), "int4");
        let root = c.call(&v(r#"{"v":3,"op":"cache.quant"}"#)).unwrap();
        assert_eq!(root.get("level").unwrap().as_str().unwrap(), "int4");

        // Opting out: "none" pins the tenant at full precision.
        let off = c
            .call(&v(r#"{"v":3,"ns":"tenant-q","op":"cache.quant","level":"none"}"#))
            .unwrap();
        assert_ok(&off);
        assert_eq!(off.get("level").unwrap().as_str().unwrap(), "none");

        // Unknown levels are rejected with a coded error.
        assert_code(
            &c.call(&v(r#"{"v":3,"op":"cache.quant","level":"int3"}"#)).unwrap(),
            "bad_value",
        );

        // The op is metered like every other cache op.
        let stats = c.call(&v(r#"{"v":3,"op":"stats"}"#)).unwrap();
        let ops = stats.get("metrics").unwrap().get("ops").unwrap();
        assert!(ops.get("cache.quant").unwrap().get("n").unwrap().as_f64().unwrap() >= 5.0);

        assert_ok(&c.call(&v(r#"{"v":3,"op":"shutdown"}"#)).unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK tcp server quant ceiling");
}

/// Satellite e2e: cancel a streaming chat mid-flight. The victim gets a
/// terminal `cancelled` line, its batch slot frees (queue_bound=1: the
/// next request admits immediately), no session turn is committed, and
/// the pipeline counts the cancellation.
fn pipeline_cancellation() {
    let engine = test_engine("cxl");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let driver = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();
        let mut admin = MpicClient::connect(addr).unwrap();
        admin.upload(1, "IMAGE#CXL").unwrap();

        // A long streaming chat holds the only in-flight slot.
        let mut victim = MpicClient::connect(addr).unwrap();
        let mut handle = victim
            .chat_stream(
                &InferParams::new(5, "Describe IMAGE#CXL in detail please")
                    .policy("mpic-16")
                    .max_new(40),
            )
            .unwrap();
        let first = handle.recv_chunk().unwrap().expect("first chunk before cancel");
        assert_eq!(first.seq, 0);

        // Cancel from a second connection (the stream occupies this one).
        handle.cancel().unwrap();

        // The stream must end with a terminal cancelled line.
        let outcome = handle.join().unwrap();
        match outcome {
            InferOutcome::Cancelled { message } => {
                assert!(message.contains("cancelled"), "victim message: {message}")
            }
            InferOutcome::Completed(r) => {
                panic!("stream must not complete ({} tokens)", r.tokens.len())
            }
        }

        // The batch slot freed: with queue_bound=1 a new generation
        // admits and completes immediately.
        let r = admin
            .infer(&InferParams::new(1, "Describe IMAGE#CXL please").policy("mpic-16").max_new(2))
            .unwrap();
        assert_eq!(r.tokens.len(), 2, "slot must be reusable after the cancel");

        // No half-committed session state: the previewed turn was never
        // committed, so user 5's session (created by the preview path)
        // holds zero turns and zero history.
        let ss = admin.call_raw(&v(r#"{"v":3,"op":"session.stat","user":5}"#), |_| {}).unwrap();
        if ss.get("ok").unwrap().as_bool().unwrap() {
            assert_eq!(ss.get("turns").unwrap().as_f64().unwrap(), 0.0, "{}", ss.encode());
            assert_eq!(ss.get("history_len").unwrap().as_f64().unwrap(), 0.0);
        } // (not_found is equally fine: no session state leaked)

        // A second turn for the same user is admittable (busy flag
        // cleared by the cancel).
        let t = victim
            .chat(&InferParams::new(5, "Look at IMAGE#CXL").policy("mpic-16").max_new(2))
            .unwrap();
        assert_eq!(t.turn, Some(1), "first committed turn after the cancelled one");

        // Cancelling an unknown id is a clean not_found; the counter
        // reflects exactly the one real cancellation.
        assert_wire_code(admin.cancel(&Value::str("no-such-id")), ErrorCode::NotFound);
        let stats = admin.stats().unwrap();
        let pipe = stats.get("metrics").unwrap().get("pipeline").unwrap();
        assert_eq!(pipe.get("cancelled").unwrap().as_f64().unwrap(), 1.0, "{}", pipe.encode());

        admin.shutdown().unwrap();
    });

    let cfg = ServeConfig {
        pipeline: PipelineConfig { queue_bound: 1, ..Default::default() },
        ..Default::default()
    };
    mpic::server::serve_with(&engine, "127.0.0.1:0", cfg, |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    driver.join().unwrap();
    println!("OK pipeline cancellation");
}

/// Satellite regression: with two calls pipelined on one connection, a
/// `call` that would read the *other* request's reply must error on the
/// id mismatch instead of silently pairing the wrong reply.
fn client_errors_on_mispaired_replies() {
    let engine = test_engine("pair");
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    let client = std::thread::spawn(move || {
        let addr = addr_rx.recv().unwrap();

        // Well-behaved pipelining: send two, receive two, ids correlate.
        let mut c = mpic::server::Client::connect(addr).unwrap();
        c.send(&v(r#"{"v":3,"id":"first","op":"ping"}"#)).unwrap();
        c.send(&v(r#"{"v":3,"id":"second","op":"ping"}"#)).unwrap();
        let r1 = c.recv().unwrap();
        let r2 = c.recv().unwrap();
        assert_eq!(r1.get("id").unwrap().as_str().unwrap(), "first");
        assert_eq!(r2.get("id").unwrap().as_str().unwrap(), "second");

        // The regression: a pipelined request's reply is still in flight
        // when `call` issues a new id — the old client would hand the
        // stale reply to the new call. Now it errors loudly.
        c.send(&v(r#"{"v":3,"id":"stale","op":"ping"}"#)).unwrap();
        let err = c.call(&v(r#"{"v":3,"id":"fresh","op":"ping"}"#)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("stale") && msg.contains("fresh"),
            "mismatch error must name both ids: {msg}"
        );

        // A clean connection still works for shutdown.
        let mut c2 = mpic::server::Client::connect(addr).unwrap();
        assert_ok(&c2.call(&v(r#"{"v":3,"id":"bye","op":"shutdown"}"#)).unwrap());
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).unwrap();
    })
    .unwrap();
    client.join().unwrap();
    println!("OK client mispaired-reply detection");
}
