//! Property-based tests on coordinator invariants (routing, batching,
//! selection, linker state) using the in-tree prop harness.

use mpic::coordinator::linker::Linker;
use mpic::coordinator::selection::{plan, Policy};
use mpic::kv::{KvKey, KvShape, SegmentKv};
use mpic::mm::{
    ChunkId, ChunkRef, ImageId, LinkedLayout, Prompt, ReuseSpan, SegmentId, Tokenizer, UserId,
};
use mpic::runtime::artifacts::{ModelMeta, WeightsMeta};
use mpic::util::prop;
use mpic::util::rng::Rng;

fn meta() -> ModelMeta {
    ModelMeta {
        name: "sim".into(),
        d_model: 8,
        n_layers: 2,
        n_heads: 2,
        d_head: 4,
        d_ff: 16,
        vocab: 4096,
        img_tokens: 8,
        patch_dim: 8,
        rope_theta: 1e4,
        sink_sigma: 3.0,
        sink_tau: 8.0,
        bos_bias: 2.0,
        weights: WeightsMeta {
            file: String::new(),
            total_bytes: 0,
            sha256: String::new(),
            tensors: vec![],
        },
    }
}

/// Random prompt mixing text, image and (resolved) chunk segments.
fn random_prompt(rng: &mut Rng, tok: &Tokenizer) -> Prompt {
    let mut p = Prompt::new(UserId(1)).text("start of the request words here");
    let n_seg = 1 + rng.below(5);
    for i in 0..n_seg {
        match rng.below(3) {
            0 => p = p.image(ImageId(100 + i)),
            1 => {
                let words = 1 + rng.below(8);
                let text: Vec<String> =
                    (0..words).map(|w| format!("doc{}", rng.below(50 + w))).collect();
                let tokens = tok.encode(&text.join(" "));
                p = p.chunk(ChunkRef::resolved(ChunkId(200 + i), tokens));
            }
            _ => {
                let words = 1 + rng.below(8);
                let text: Vec<String> =
                    (0..words).map(|w| format!("w{}", rng.below(50 + w))).collect();
                p = p.text(&text.join(" "));
            }
        }
    }
    p.text("final question mark")
}

fn entry_for(meta: &ModelMeta, span: &ReuseSpan) -> SegmentKv {
    let shape = KvShape {
        layers: meta.n_layers,
        tokens: span.len(),
        heads: meta.n_heads,
        d_head: meta.d_head,
        d_model: meta.d_model,
    };
    let mut rng = Rng::new(span.seg.raw());
    let emb = match span.seg {
        SegmentId::Image(_) => (0..shape.emb_elems()).map(|_| rng.f32()).collect(),
        SegmentId::Chunk(_) => Vec::new(),
    };
    let key = KvKey { model: meta.name.clone(), ns: Default::default(), seg: span.seg };
    SegmentKv {
        key,
        shape,
        emb,
        k: (0..shape.kv_elems()).map(|_| rng.f32()).collect(),
        v: (0..shape.kv_elems()).map(|_| rng.f32()).collect(),
    }
}

/// MPIC selection is deterministic, sorted, covers text ∪ span-heads, and
/// always includes the final token.
#[test]
fn prop_mpic_selection_invariants() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    prop::check(
        "mpic-selection-invariants",
        60,
        |rng| (random_prompt(rng, &Tokenizer::new(4096)), rng.below(12) as usize),
        |(prompt, k)| {
            let layout = LinkedLayout::build(prompt, &tok, m.img_tokens, "sys");
            let a = plan(Policy::MpicK(*k), &layout, &[]);
            let b = plan(Policy::MpicK(*k), &layout, &[]);
            if a.selected != b.selected {
                return Err("selection not deterministic".into());
            }
            if a.selected.windows(2).any(|w| w[0] >= w[1]) {
                return Err("selection not strictly sorted".into());
            }
            if *a.selected.last().unwrap() != layout.len() - 1 {
                return Err("final token not selected".into());
            }
            for &i in &layout.text_indices() {
                if !a.selected.contains(&i) {
                    return Err(format!("text token {i} not selected"));
                }
            }
            // Budget: |selected| <= text + k * n_spans (+1 for last token).
            let bound = layout.text_len() + k * layout.reuse_spans.len() + 1;
            if a.selected.len() > bound {
                return Err(format!("selection {} exceeds bound {bound}", a.selected.len()));
            }
            Ok(())
        },
    );
}

/// The linked cache contains exactly the stored rows at reuse slots and
/// zeros elsewhere, for random prompts (image and chunk spans).
#[test]
fn prop_linked_cache_placement() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    let linker = Linker::new(&m);
    prop::check(
        "linked-cache-placement",
        40,
        |rng| random_prompt(rng, &Tokenizer::new(4096)),
        |prompt| {
            let layout = LinkedLayout::build(prompt, &tok, m.img_tokens, "sys");
            let entries: Vec<SegmentKv> =
                layout.reuse_spans.iter().map(|s| entry_for(&m, s)).collect();
            let refs: Vec<&SegmentKv> = entries.iter().collect();
            let bucket = layout.len().next_multiple_of(128);
            let (k, _) = linker.linked_cache(&layout, &refs, bucket).map_err(|e| e.to_string())?;
            let row = m.n_heads * m.d_head;
            let reuse_slots: std::collections::HashSet<usize> =
                layout.reuse_indices().into_iter().collect();
            for layer in 0..m.n_layers {
                for slot in 0..bucket {
                    let base = layer * bucket * row + slot * row;
                    let nonzero = k[base..base + row].iter().any(|&x| x != 0.0);
                    if reuse_slots.contains(&slot) {
                        if !nonzero {
                            return Err(format!("reuse slot {slot} layer {layer} is zero"));
                        }
                    } else if nonzero {
                        return Err(format!("non-reuse slot {slot} layer {layer} not zero"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// CacheBlend's budget: the number of recomputed reused tokens equals
/// ceil(r% · n_reuse_tokens), regardless of the deviation values.
#[test]
fn prop_cacheblend_budget() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    prop::check(
        "cacheblend-budget",
        40,
        |rng| {
            let prompt = random_prompt(rng, &Tokenizer::new(4096));
            let r = 1.0 + rng.f64() * 50.0;
            (prompt, r, rng.next_u64())
        },
        |(prompt, r, seed)| {
            let layout = LinkedLayout::build(prompt, &tok, m.img_tokens, "sys");
            if layout.reuse_indices().is_empty() {
                return Ok(()); // nothing to blend
            }
            let mut rng = Rng::new(*seed);
            let dev: Vec<f32> = (0..layout.len()).map(|_| rng.f32()).collect();
            let pl = plan(Policy::CacheBlend(*r), &layout, &dev);
            let n_reuse = layout.reuse_indices().len();
            let expect = ((r / 100.0) * n_reuse as f64).ceil() as usize;
            let reuse_selected =
                pl.selected.iter().filter(|&&i| i != layout.len() - 1).count();
            // The last token may or may not be a reused token; allow ±1.
            if reuse_selected.abs_diff(expect) > 1 {
                return Err(format!("selected {reuse_selected} reused tokens, expected ~{expect}"));
            }
            Ok(())
        },
    );
}

/// Tokenizer × layout: token count is invariant under re-tokenization and
/// reuse spans tile exactly.
#[test]
fn prop_layout_structure() {
    let m = meta();
    let tok = Tokenizer::new(m.vocab);
    prop::check(
        "layout-structure",
        60,
        |rng| random_prompt(rng, &Tokenizer::new(4096)),
        |prompt| {
            let a = LinkedLayout::build(prompt, &tok, m.img_tokens, "sys");
            let b = LinkedLayout::build(prompt, &tok, m.img_tokens, "sys");
            if a.len() != b.len() {
                return Err("layout not deterministic".into());
            }
            let mut covered = vec![false; a.len()];
            for span in &a.reuse_spans {
                if matches!(span.seg, SegmentId::Image(_)) && span.len() != m.img_tokens {
                    return Err("image span length != img_tokens".into());
                }
                for slot in span.lo..span.hi {
                    if covered[slot] {
                        return Err("overlapping reuse spans".into());
                    }
                    covered[slot] = true;
                }
            }
            let text = a.text_indices().len();
            let reused: usize = a.reuse_spans.iter().map(|s| s.len()).sum();
            if text + reused != a.len() {
                return Err("text+reuse != total".into());
            }
            if reused != a.reuse_indices().len() {
                return Err("span lengths disagree with reuse_indices".into());
            }
            Ok(())
        },
    );
}

/// Quality scorer properties: exactness ⇒ 10; score monotone in agreement.
#[test]
fn prop_scorer_monotonicity() {
    prop::check(
        "scorer-monotone",
        50,
        |rng| {
            let n = 8;
            let logits: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect();
            let tokens: Vec<i32> = (0..n).map(|_| rng.below(100) as i32).collect();
            let flips = rng.below(n as u64 + 1) as usize;
            (logits, tokens, flips)
        },
        |(logits, tokens, flips)| {
            use mpic::coordinator::engine::{InferenceResult, TtftBreakdown};
            use mpic::kv::TransferReport;
            let mk = |toks: Vec<i32>| InferenceResult {
                policy: "x".into(),
                tokens: toks,
                first_logits: logits.clone(),
                ttft: TtftBreakdown::default(),
                transfer: TransferReport::default(),
                decode_s: 0.0,
                seq_len: 1,
                n_selected: 1,
                s_bucket: 128,
            };
            let reference = mk(tokens.clone());
            let mut worse = tokens.clone();
            for f in worse.iter_mut().take(*flips) {
                *f += 1000;
            }
            let s_exact = mpic::quality::score(&reference, &mk(tokens.clone()));
            let s_worse = mpic::quality::score(&reference, &mk(worse));
            if (s_exact.score - 10.0).abs() > 1e-9 {
                return Err("exact must score 10".into());
            }
            if s_worse.score > s_exact.score + 1e-9 {
                return Err("more flips must not raise the score".into());
            }
            Ok(())
        },
    );
}
