//! Cluster end-to-end: two real workers over real artifacts, the peer KV
//! lane (`kv.probe`/`kv.pull`) between them, and the cache-aware router
//! in front. Proves the PR's acceptance claims on the live wire:
//!
//! * a worker serving a prompt whose segment was uploaded *elsewhere*
//!   pulls the encoded container from its peer instead of recomputing
//!   (`stats.metrics.cluster.peer_pulls` ≥ 1, `recomputes` stays 0);
//! * position independence makes the pulled cache byte-equivalent —
//!   both workers decode the same tokens for the same prompt;
//! * a `kv.pull` carrying a `groups` range returns the self-contained
//!   shallow prefix of the container (the streamed fetch's fast first
//!   phase) — parseable, decodable, exactly the advertised length;
//! * uploads routed through `mpic router` land on the consistent-hash
//!   ring owner, and a generation referencing that segment is routed
//!   back to it (`routed_affinity_hits` ≥ 1 on the owner);
//! * with int8 tier floors on the owner, the container a peer pulls is
//!   the *quantized* v6 format end-to-end, and the pull still pre-empts
//!   the recompute entirely (`recomputes` stays 0).
//!
//! Skips when artifacts are not built (same contract as `serving_e2e`).

use std::net::SocketAddr;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;

use mpic::cluster::{serve_router, HashRing, PeerConfig, PeerTransport, RouterConfig};
use mpic::coordinator::{Engine, EngineConfig};
use mpic::kv::QuantLevel;
use mpic::mm::{ImageId, Namespace, SegmentId};
use mpic::server::{serve_with, Client, ServeConfig};
use mpic::util::json::Value;

fn artifacts_ready() -> bool {
    let ready = std::path::Path::new("artifacts/manifest.json").exists();
    if !ready && std::env::var("MPIC_REQUIRE_ARTIFACTS").map_or(false, |v| !v.is_empty()) {
        panic!("MPIC_REQUIRE_ARTIFACTS is set but artifacts/manifest.json is missing");
    }
    ready
}

fn v(s: &str) -> Value {
    Value::parse(s).unwrap()
}

fn assert_ok(resp: &Value) {
    assert!(resp.get("ok").unwrap().as_bool().unwrap(), "expected ok: {}", resp.encode());
}

fn assert_code(resp: &Value, code: &str) {
    assert!(!resp.get("ok").unwrap().as_bool().unwrap(), "expected error: {}", resp.encode());
    assert_eq!(resp.get("code").unwrap().as_str().unwrap(), code, "{}", resp.encode());
}

/// Spawn one worker on its own thread (the engine and PJRT stay on the
/// serving thread, as in `serving_e2e`). `peers` installs a
/// [`PeerTransport`] so this worker's local misses consult them;
/// `quant` sets both compressed-tier floors (host and disk).
fn spawn_worker(
    tag: &'static str,
    peers: Vec<SocketAddr>,
    quant: QuantLevel,
) -> (SocketAddr, JoinHandle<()>) {
    let (tx, rx) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let dir = std::env::temp_dir().join(format!("mpic-cluster-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut engine = Engine::new(EngineConfig {
            model: "mpic-sim-a".into(),
            store: mpic::kv::StoreConfig {
                disk_dir: dir,
                host_quant: quant,
                disk_quant: quant,
                ..Default::default()
            },
            max_new_tokens: 4,
            ..Default::default()
        })
        .expect("engine");
        if !peers.is_empty() {
            let counters = Arc::clone(engine.metrics.cluster());
            engine.set_transport(Arc::new(PeerTransport::new(
                peers,
                PeerConfig::default(),
                counters,
            )));
        }
        serve_with(&engine, "127.0.0.1:0", ServeConfig::default(), |a| {
            tx.send(a).unwrap();
        })
        .expect("serve");
    });
    (rx.recv().unwrap(), handle)
}

/// The first IMAGE# handle in a deterministic family whose segment the
/// 2-worker ring assigns to `owner` — so routed uploads land where the
/// test expects without hard-coding hash values.
fn handle_owned_by(ring: &HashRing, owner: usize) -> String {
    (0..256)
        .map(|i| format!("IMAGE#cluster-e2e-{i}"))
        .find(|h| {
            ring.owner(&Namespace::default(), SegmentId::Image(ImageId::from_handle(h))) == owner
        })
        .expect("some handle in 256 tries must map to this owner")
}

fn cluster_counter(stats: &Value, name: &str) -> f64 {
    stats
        .get("metrics")
        .unwrap()
        .get("cluster")
        .unwrap()
        .get(name)
        .unwrap()
        .as_f64()
        .unwrap()
}

fn shutdown_worker(addr: SocketAddr, handle: JoinHandle<()>) {
    let mut c = Client::connect(addr).unwrap();
    let resp = c.call(&v(r#"{"v":3,"id":"bye","op":"shutdown"}"#)).unwrap();
    assert_ok(&resp);
    handle.join().unwrap();
}

#[test]
fn cluster_end_to_end() {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    routed_cluster();
    quantized_peer_lane();
}

/// The full-precision cluster path: peer pull, group-range prefix pull,
/// router placement and affinity routing.
fn routed_cluster() {
    // Worker A is standalone; worker B peers with A.
    let (a_addr, a_join) = spawn_worker("a", vec![], QuantLevel::None);
    let (b_addr, b_join) = spawn_worker("b", vec![a_addr], QuantLevel::None);
    let ring = HashRing::new(2);

    // ------------------------------------------------------------------
    // Peer KV lane: upload on A, infer on B, B pulls instead of
    // recomputing.
    // ------------------------------------------------------------------
    let handle = handle_owned_by(&ring, 0);
    let mut ca = Client::connect(a_addr).unwrap();
    let mut cb = Client::connect(b_addr).unwrap();

    let up = ca
        .call(&v(&format!(r#"{{"v":3,"id":"u1","op":"upload","user":1,"handle":"{handle}"}}"#)))
        .unwrap();
    assert_ok(&up);
    // B has never seen the handle: its static library misses.
    let stat_b = cb
        .call(&v(&format!(r#"{{"v":3,"id":"s1","op":"cache.stat","handle":"{handle}"}}"#)))
        .unwrap();
    assert_code(&stat_b, "not_found");

    let infer_req = |id: &str| {
        v(&format!(
            r#"{{"v":3,"id":"{id}","op":"infer","user":1,"text":"describe {handle} briefly","max_new":4}}"#
        ))
    };
    let on_a = ca.call(&infer_req("i-a")).unwrap();
    assert_ok(&on_a);
    let on_b = cb.call(&infer_req("i-b")).unwrap();
    assert_ok(&on_b);
    // Position independence on the wire: the pulled container decodes to
    // the same generation the owner produced.
    assert_eq!(
        on_a.get("tokens").unwrap(),
        on_b.get("tokens").unwrap(),
        "peer-pulled KV must decode identically (a={}, b={})",
        on_a.encode(),
        on_b.encode()
    );

    let b_stats = cb.call(&v(r#"{"v":3,"id":"st-b","op":"stats"}"#)).unwrap();
    assert_ok(&b_stats);
    assert!(
        cluster_counter(&b_stats, "peer_pulls") >= 1.0,
        "B must have pulled the container from A: {}",
        b_stats.encode()
    );
    assert!(cluster_counter(&b_stats, "peer_probes") >= 1.0);
    assert!(cluster_counter(&b_stats, "peer_pull_bytes") > 0.0);
    assert_eq!(
        cluster_counter(&b_stats, "recomputes"),
        0.0,
        "the peer hit must have pre-empted the recompute: {}",
        b_stats.encode()
    );

    // ------------------------------------------------------------------
    // Group-range pull on the live wire: ask A for only the first layer
    // group of the uploaded segment's container (the streamed fetch's
    // fast first phase). The reply must be the self-contained prefix —
    // parseable, with exactly the advertised groups decodable.
    // ------------------------------------------------------------------
    let seg_hex = format!("{:016x}", ImageId::from_handle(&handle).0);
    let pull = ca
        .call(&v(&format!(
            r#"{{"v":3,"id":"p1","op":"kv.pull","model":"mpic-sim-a","kind":"image","segment":"{seg_hex}","groups":1}}"#
        )))
        .unwrap();
    assert_ok(&pull);
    let bytes =
        mpic::kv::codec::unframe(pull.get("frame").unwrap().as_str().unwrap()).unwrap();
    let info = mpic::kv::codec::parse_container(&bytes).unwrap();
    let served = pull.get("groups").unwrap().as_f64().unwrap() as usize;
    let n_groups = pull.get("n_groups").unwrap().as_f64().unwrap() as usize;
    assert_eq!(served, 1, "groups:1 must cap the reply to one group: {}", pull.encode());
    assert_eq!(n_groups, info.n_groups());
    assert_eq!(bytes.len(), info.prefix_len(served), "reply must be the exact prefix");
    assert_eq!(info.groups_available(bytes.len()), served);
    mpic::kv::codec::decode_group(&info, &bytes, 0).expect("prefix group must decode");

    // ------------------------------------------------------------------
    // Router: ring placement for uploads, affinity routing for
    // generations.
    // ------------------------------------------------------------------
    let (rtx, rrx) = mpsc::channel();
    let router_cfg = RouterConfig::new(vec![a_addr, b_addr]);
    let router_join = std::thread::spawn(move || {
        serve_router(router_cfg, "127.0.0.1:0", |a| rtx.send(a).unwrap()).unwrap();
    });
    let router_addr = rrx.recv().unwrap();
    let mut cr = Client::connect(router_addr).unwrap();

    // A fresh segment owned by worker 0 (= A): the routed upload must
    // land there and only there.
    let routed_handle = (0..256)
        .map(|i| format!("IMAGE#cluster-e2e-routed-{i}"))
        .find(|h| {
            ring.owner(&Namespace::default(), SegmentId::Image(ImageId::from_handle(h))) == 0
        })
        .expect("some routed handle in 256 tries must map to worker 0");
    let up = cr
        .call(&v(&format!(
            r#"{{"v":3,"id":"u2","op":"upload","user":1,"handle":"{routed_handle}"}}"#
        )))
        .unwrap();
    assert_ok(&up);
    let stat_a = ca
        .call(&v(&format!(r#"{{"v":3,"id":"s2","op":"cache.stat","handle":"{routed_handle}"}}"#)))
        .unwrap();
    assert_ok(&stat_a);
    let stat_b = cb
        .call(&v(&format!(r#"{{"v":3,"id":"s3","op":"cache.stat","handle":"{routed_handle}"}}"#)))
        .unwrap();
    assert_code(&stat_b, "not_found");

    // Generation through the router: the reuse span lives on A, so
    // affinity must route there and stamp the request.
    let hits_before = {
        let s = ca.call(&v(r#"{"v":3,"id":"st-a0","op":"stats"}"#)).unwrap();
        cluster_counter(&s, "routed_affinity_hits")
    };
    let gen = cr
        .call(&v(&format!(
            r#"{{"v":3,"id":"g1","op":"infer","user":1,"text":"summarize {routed_handle} now","max_new":4}}"#
        )))
        .unwrap();
    assert_ok(&gen);
    let hits_after = {
        let s = ca.call(&v(r#"{"v":3,"id":"st-a1","op":"stats"}"#)).unwrap();
        cluster_counter(&s, "routed_affinity_hits")
    };
    assert!(
        hits_after > hits_before,
        "affinity-routed generation must land on the span owner (before={hits_before}, after={hits_after})"
    );

    // ------------------------------------------------------------------
    // Teardown.
    // ------------------------------------------------------------------
    let bye = cr.call(&v(r#"{"v":3,"id":"rbye","op":"shutdown"}"#)).unwrap();
    assert_ok(&bye);
    router_join.join().unwrap();
    drop(ca);
    drop(cb);
    shutdown_worker(a_addr, a_join);
    shutdown_worker(b_addr, b_join);
    println!("OK routed cluster");
}

/// Compressed tiers on the live wire: worker A's host/disk floors are
/// int8, so the container it serves peers is the quantized v6 format —
/// pulled, admitted and dequantized on B with zero recomputes.
fn quantized_peer_lane() {
    let (a_addr, a_join) = spawn_worker("qa", vec![], QuantLevel::Int8);
    let (b_addr, b_join) = spawn_worker("qb", vec![a_addr], QuantLevel::None);
    let handle = "IMAGE#cluster-e2e-quant";
    let mut ca = Client::connect(a_addr).unwrap();
    let mut cb = Client::connect(b_addr).unwrap();

    let up = ca
        .call(&v(&format!(r#"{{"v":3,"id":"qu","op":"upload","user":1,"handle":"{handle}"}}"#)))
        .unwrap();
    assert_ok(&up);

    // What A serves the peer lane really is a v6 quantized container:
    // pull it directly and sniff the header.
    let seg_hex = format!("{:016x}", ImageId::from_handle(handle).0);
    let pull = ca
        .call(&v(&format!(
            r#"{{"v":3,"id":"qp","op":"kv.pull","model":"mpic-sim-a","kind":"image","segment":"{seg_hex}"}}"#
        )))
        .unwrap();
    assert_ok(&pull);
    let bytes = mpic::kv::codec::unframe(pull.get("frame").unwrap().as_str().unwrap()).unwrap();
    let info = mpic::kv::codec::parse_container(&bytes).unwrap();
    assert_eq!(info.version, 6, "int8 tier floors must produce v6 containers");
    assert_eq!(info.max_quant(), QuantLevel::Int8, "container must carry the int8 level");

    // Infer on B: the quantized container must serve the whole reuse
    // span — pulled from A, never recomputed.
    let gen = cb
        .call(&v(&format!(
            r#"{{"v":3,"id":"qi","op":"infer","user":1,"text":"describe {handle} briefly","max_new":4}}"#
        )))
        .unwrap();
    assert_ok(&gen);
    let b_stats = cb.call(&v(r#"{"v":3,"id":"qs","op":"stats"}"#)).unwrap();
    assert_ok(&b_stats);
    assert!(
        cluster_counter(&b_stats, "peer_pulls") >= 1.0,
        "B must have pulled the quantized container from A: {}",
        b_stats.encode()
    );
    assert_eq!(
        cluster_counter(&b_stats, "recomputes"),
        0.0,
        "the quantized peer hit must still pre-empt the recompute: {}",
        b_stats.encode()
    );

    drop(ca);
    drop(cb);
    shutdown_worker(a_addr, a_join);
    shutdown_worker(b_addr, b_join);
    println!("OK quantized peer lane");
}
