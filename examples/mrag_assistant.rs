//! MRAG assistant: the paper's second motivating scenario (Fig. 1, round
//! 2). An administrator populates the Dynamic Library with multimedia
//! references; queries retrieve the relevant ones and the Linker splices
//! their (position-independently cached) KV into the prompt.
//!
//! ```sh
//! cargo run --release --example mrag_assistant
//! ```

use mpic::coordinator::Policy;
use mpic::harness;
use mpic::mm::{Prompt, UserId};
use mpic::quality;

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let engine = harness::experiment_engine("mpic-sim-a", "mrag")?;

    // Admin path: refresh the dynamic library (workflow: references + KV
    // precomputed so retrieval-time linking is cache-hit only).
    let refs = [
        ("IMAGE#HOTEL01", "boutique hotel lobby near the eiffel tower in paris"),
        ("IMAGE#HOTEL02", "budget hostel common room by the louvre museum"),
        ("IMAGE#HOTEL03", "riverside guesthouse with seine views"),
        ("IMAGE#BIKE01", "dirt bike race through the desert canyon"),
        ("IMAGE#MARKET01", "covered food market with cheese stalls"),
        ("IMAGE#GARDEN01", "tuileries garden fountain at sunset"),
    ];
    for (handle, desc) in refs {
        engine.add_reference(handle, desc)?;
    }
    // Text chunks are retrievable too: their KV is cached once and spliced
    // position-independently, exactly like image references.
    engine.add_chunk_reference(
        "CHUNK#GUIDE01",
        "The quiet tuileries garden and the nearby royal gardens are best visited \
         in the early evening when the fountains catch the low light",
        "guidebook chapter on quiet evening gardens in paris",
    )?;
    println!("dynamic library: {} references indexed", engine.dynamic_lib.len());

    let user = UserId(7);
    let queries = [
        "We are visiting paris next month can you recommend hotels near the eiffel tower",
        "Where can we taste local cheese at a market while we are there",
        "Suggest something green and quiet for the evening walk",
    ];
    for q in queries {
        let prompt = Prompt::new(user).text(q);
        let (augmented, hits) = engine.mrag_augment(&prompt, 2)?;
        println!("\nquery: {q}");
        for (i, seg) in hits.iter().enumerate() {
            let r = engine.dynamic_lib.by_segment(*seg)?;
            println!("  retrieved {} ({}): {}", i + 1, seg.kind_str(), r.description);
        }
        // Retrieved references are cached → MPIC links them with no
        // recompute beyond the text and each reference's head tokens.
        let exact = engine.infer(&augmented, Policy::Prefix, 8)?;
        let mpic = engine.infer(&augmented, Policy::MpicK(32), 8)?;
        let s = quality::score(&exact, &mpic);
        println!(
            "  prefix TTFT {:6.1} ms | mpic-32 TTFT {:6.1} ms ({:.0}% faster, score {:.2}/10)",
            exact.ttft.total_s * 1e3,
            mpic.ttft.total_s * 1e3,
            100.0 * (1.0 - mpic.ttft.total_s / exact.ttft.total_s),
            s.score
        );
    }
    Ok(())
}
