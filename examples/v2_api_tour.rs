//! Tour of the v2 serving API over live TCP: versioned envelopes with
//! request-id echo, cache-management ops over the Static Library's tiered
//! residency, session introspection, and streaming decode.
//!
//! ```sh
//! cargo run --release --example v2_api_tour
//! ```

use mpic::harness;
use mpic::server::Client;
use mpic::util::json::Value;

fn req(s: &str) -> Value {
    Value::parse(s).expect("request literal")
}

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let engine = harness::experiment_engine("mpic-sim-a", "v2-tour")?;
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    // The engine loop owns this thread (PJRT); the tour drives it from a
    // client thread, exactly like an external caller would.
    let tour = std::thread::spawn(move || -> mpic::Result<()> {
        let addr = addr_rx.recv().expect("server address");
        let mut c = Client::connect(addr)?;

        println!("== upload (v2 envelope, id echo) ==");
        let up = c.call(&req(
            r#"{"v":2,"id":"up-1","op":"upload","user":7,"handle":"IMAGE#EIFFEL2025"}"#,
        ))?;
        println!("  {}", up.encode());

        println!("== cache.list / cache.stat: tier residency ==");
        let list = c.call(&req(r#"{"v":2,"id":"ls-1","op":"cache.list"}"#))?;
        println!("  {}", list.encode());
        let stat =
            c.call(&req(r#"{"v":2,"op":"cache.stat","handle":"IMAGE#EIFFEL2025"}"#))?;
        println!("  {}", stat.encode());

        println!("== cache.pin protects the entry; evict is refused ==");
        let pin = c.call(&req(r#"{"v":2,"op":"cache.pin","handle":"IMAGE#EIFFEL2025"}"#))?;
        println!("  {}", pin.encode());
        let refused =
            c.call(&req(r#"{"v":2,"op":"cache.evict","handle":"IMAGE#EIFFEL2025"}"#))?;
        println!("  {} (code={})", refused.encode(), refused.get("code")?.as_str()?);

        println!("== streaming decode: one line per token ==");
        let fin = c.call_stream(
            &req(
                r#"{"v":2,"id":"gen-1","op":"infer","user":7,"policy":"mpic-32","max_new":6,
                    "stream":true,"text":"Describe IMAGE#EIFFEL2025 in detail please"}"#,
            ),
            |chunk| println!("  chunk {}", chunk.encode()),
        )?;
        println!("  final {}", fin.encode());

        println!("== sessions: chat then introspect ==");
        let t1 = c.call(&req(
            r#"{"v":2,"op":"chat","user":7,"max_new":4,"text":"And what about IMAGE#EIFFEL2025?"}"#,
        ))?;
        println!("  turn={}", t1.get("turn")?.as_f64()?);
        let sessions = c.call(&req(r#"{"v":2,"op":"session.list"}"#))?;
        println!("  {}", sessions.encode());

        println!("== per-op metrics in stats ==");
        let stats = c.call(&req(r#"{"v":2,"op":"stats"}"#))?;
        println!("  ops = {}", stats.get("metrics")?.get("ops")?.encode());

        c.call(&req(r#"{"v":2,"op":"shutdown"}"#))?;
        Ok(())
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).expect("publish address");
    })?;
    tour.join().expect("tour thread")?;
    println!("v2 API tour complete ✓");
    Ok(())
}
