//! Quickstart: upload two images, ask an interleaved question, and compare
//! MPIC-32 against prefix caching on the same request.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use mpic::coordinator::Policy;
use mpic::harness;
use mpic::mm::{ImageId, Prompt, UserId};
use mpic::quality;

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }

    // 1. Start an engine (loads AOT artifacts, compiles them, keeps the
    //    model weights resident on the PJRT device).
    let engine = harness::experiment_engine("mpic-sim-a", "quickstart")?;
    let user = UserId(42);

    // 2. Upload images (workflow ①): the vision encoder + prefill run once,
    //    and the KV cache lands in the static library (device + disk).
    engine.upload_image(user, "IMAGE#EIFFEL2025")?;
    engine.upload_image(user, "IMAGE#LOUVRE2025")?;
    println!("uploaded 2 images; store residency = {:?}", engine.store().residency());

    // 3. Ask a question that interleaves text and images (paper Fig. 1).
    let prompt = Prompt::new(user)
        .text("My partner and I took these photos during our trip")
        .image(ImageId::from_handle("IMAGE#EIFFEL2025"))
        .image(ImageId::from_handle("IMAGE#LOUVRE2025"))
        .text("Please describe the landmarks and share their history");

    // 4. Exact baseline (prefix caching = full recompute of the prompt).
    let exact = engine.infer(&prompt, Policy::Prefix, 16)?;
    println!(
        "prefix caching : TTFT {:6.1} ms  (exact reference, score 10)",
        exact.ttft.total_s * 1e3
    );

    // 5. MPIC: single-pass selective attention over the cached image KV.
    let mpic = engine.infer(&prompt, Policy::MpicK(32), 16)?;
    let s = quality::score(&exact, &mpic);
    println!(
        "mpic-32        : TTFT {:6.1} ms  ({}x faster, score {:.2}/10, KL {:.2e})",
        mpic.ttft.total_s * 1e3,
        (exact.ttft.total_s / mpic.ttft.total_s).round(),
        s.score,
        s.kl_first
    );
    println!(
        "mpic recomputed {} of {} tokens in 1 engine step",
        mpic.n_selected, mpic.seq_len
    );

    // 6. Re-ask with different opening words — the case that breaks
    //    prefix-based caching but not MPIC.
    let prompt2 = Prompt::new(user)
        .text("We are planning to revisit these places")
        .image(ImageId::from_handle("IMAGE#EIFFEL2025"))
        .image(ImageId::from_handle("IMAGE#LOUVRE2025"))
        .text("Which one should we prioritise and why");
    let mpic2 = engine.infer(&prompt2, Policy::MpicK(32), 16)?;
    println!(
        "different opening words: MPIC still reuses both image caches (TTFT {:.1} ms, {} device hits)",
        mpic2.ttft.total_s * 1e3,
        mpic2.transfer.device_hits
    );

    // 7. The asymptotic win: a photo-album question over 8 images.
    let mut album = Prompt::new(user).text("Here is our whole album");
    for i in 0..8 {
        let handle = format!("IMAGE#ALBUM{i}");
        engine.upload_image(user, &handle)?;
        album = album.image(ImageId::from_handle(&handle));
    }
    album = album.text("Summarise the trip these photos describe");
    let exact8 = engine.infer(&album, Policy::Prefix, 16)?;
    let mpic8 = engine.infer(&album, Policy::MpicK(32), 16)?;
    let s8 = quality::score(&exact8, &mpic8);
    println!(
        "8-image album  : prefix {:6.1} ms vs mpic-32 {:6.1} ms ({:.0}% faster, score {:.2}/10)",
        exact8.ttft.total_s * 1e3,
        mpic8.ttft.total_s * 1e3,
        100.0 * (1.0 - mpic8.ttft.total_s / exact8.ttft.total_s),
        s8.score
    );
    Ok(())
}
