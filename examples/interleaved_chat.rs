//! The paper's Fig. 1 dialogue as a live multi-turn session: interleaved
//! text/images in round 1, retrieval in round 2, with per-turn TTFT
//! comparison between prefix caching and MPIC.
//!
//! ```sh
//! cargo run --release --example interleaved_chat
//! ```

use mpic::coordinator::session::SessionStore;
use mpic::coordinator::Policy;
use mpic::harness;
use mpic::mm::{Prompt, UserId};

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let engine = harness::experiment_engine("mpic-sim-a", "chat")?;
    let user = UserId(2025);

    // The user's photo uploads.
    engine.upload_image(user, "IMAGE#EIFFEL2025")?;
    engine.upload_image(user, "IMAGE#LOUVRE2025")?;
    // The assistant's retrievable references.
    engine.add_reference("IMAGE#HOTEL01", "hotel facade near the eiffel tower")?;
    engine.add_reference("IMAGE#HOTEL02", "hotel room with louvre view")?;

    let mut sessions = SessionStore::new();

    // ---- round 1: interleaved text and images -------------------------
    let turn1 = Prompt::parse(
        user,
        "My partner and I took these photos IMAGE#EIFFEL2025 IMAGE#LOUVRE2025 \
         during our trip. Please describe the landmarks and their history.",
    );
    let full1 = sessions.session(&Default::default(), user).user_turn(user, &turn1);
    let exact1 = engine.infer(&full1, Policy::Prefix, 12)?;
    let mpic1 = engine.infer(&full1, Policy::MpicK(32), 12)?;
    println!("round 1 (interleaved text+images, {} tokens):", mpic1.seq_len);
    println!(
        "  prefix {:6.1} ms | mpic-32 {:6.1} ms | reused {} image tokens verbatim",
        exact1.ttft.total_s * 1e3,
        mpic1.ttft.total_s * 1e3,
        mpic1.seq_len - mpic1.n_selected,
    );
    sessions.session(&Default::default(), user).assistant_reply(&mpic1.tokens);

    // ---- round 2: retrieval ---------------------------------------------
    let turn2 = Prompt::parse(user, "We plan to visit both. Can you recommend hotels nearby?");
    let full2 = sessions.session(&Default::default(), user).user_turn(user, &turn2);
    let (augmented, hits) = engine.mrag_augment(&full2, 2)?;
    println!("\nround 2 (MRAG): retrieved {} references", hits.len());
    let exact2 = engine.infer(&augmented, Policy::Prefix, 12)?;
    let mpic2 = engine.infer(&augmented, Policy::MpicK(32), 12)?;
    println!(
        "  history + retrieval = {} tokens; prefix {:6.1} ms | mpic-32 {:6.1} ms ({:.0}% faster)",
        mpic2.seq_len,
        exact2.ttft.total_s * 1e3,
        mpic2.ttft.total_s * 1e3,
        100.0 * (1.0 - mpic2.ttft.total_s / exact2.ttft.total_s),
    );
    println!(
        "  transfer: {} device hits, {} misses (all of round 1's images hit)",
        mpic2.transfer.device_hits, mpic2.transfer.misses
    );

    // The punchline of position independence: round 2's prompt has a
    // *different prefix* (new opening words), yet every image KV was
    // reused at a new position without recomputation.
    assert!(mpic2.transfer.device_hits >= 2);
    println!("\nposition-independent reuse confirmed across turns ✓");
    Ok(())
}
