//! End-to-end serving driver (the EXPERIMENTS.md §E2E run).
//!
//! Loads a real (small) model from the AOT artifacts, uploads the workload's
//! images, then serves batched multi-turn MMDU-like requests through the
//! continuous-batching scheduler under every CC policy, reporting
//! latency/throughput and quality vs the exact reference.
//!
//! ```sh
//! cargo run --release --example serve_mmdu -- --convs 8 --turns 2 --max-new 8
//! ```

use mpic::coordinator::scheduler::{Request, Scheduler};
use mpic::coordinator::session::SessionStore;
use mpic::coordinator::Policy;
use mpic::harness;
use mpic::quality;
use mpic::util::bench::{emit, Row, Table};
use mpic::util::cli::Args;
use mpic::util::stats::Samples;
use mpic::workload::{generate, Dataset, WorkloadSpec};

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let args = Args::parse(&[])?;
    let model = args.str_or("model", "mpic-sim-a");
    let convs = args.usize_or("convs", 8)?;
    let turns = args.usize_or("turns", 2)?;
    let max_new = args.usize_or("max-new", 8)?;

    let engine = harness::experiment_engine(&model, "serve-mmdu")?;
    let spec = WorkloadSpec {
        dataset: Dataset::Mmdu,
        n_conversations: convs,
        turns_per_conversation: turns,
        images_min: 2,
        images_max: 4,
        seed: 0x5E21,
    };
    let cs = generate(&spec);
    let uploaded = harness::precompute_images(&engine, &cs)?;
    println!("precomputed {uploaded} image KV caches (workflow ①)");

    // Expand multi-turn conversations into full prompts via sessions.
    let mut prompts = Vec::new();
    for c in &cs {
        let mut sessions = SessionStore::new();
        for turn in &c.turns {
            let full = sessions.session(&Default::default(), c.user).user_turn(c.user, turn);
            prompts.push(full);
            sessions.session(&Default::default(), c.user).assistant_reply(&[1, 2, 3]);
        }
    }
    println!("serving {} requests ({} convs × {} turns)", prompts.len(), convs, turns);

    // Exact references for scoring.
    let (refs, _) = harness::exact_references(&engine, &prompts, max_new)?;

    let mut table = Table::new(&format!(
        "E2E serving: {model}, MMDU-like, {} requests, continuous batching",
        prompts.len()
    ));
    for policy in [Policy::Prefix, Policy::FullReuse, Policy::CacheBlend(15.0), Policy::MpicK(32)]
    {
        let mut sched = Scheduler::new(8192, 16);
        for (i, p) in prompts.iter().enumerate() {
            sched.submit(Request { id: i as u64, prompt: p.clone(), policy, max_new });
        }
        let t0 = std::time::Instant::now();
        let completions = sched.run_to_completion(&engine)?;
        let wall = t0.elapsed().as_secs_f64();

        let mut ttft = Samples::new();
        let mut score = Samples::new();
        let mut tokens_out = 0usize;
        for c in &completions {
            let Ok(r) = &c.outcome else {
                eprintln!("request {} rejected: {:?}", c.id, c.outcome.as_ref().err());
                continue;
            };
            ttft.push(r.ttft.total_s);
            tokens_out += r.tokens.len();
            let s = quality::score(&refs[c.id as usize], r);
            score.push(s.score);
        }
        table.add(
            Row::new()
                .str("policy", &policy.name())
                .num("ttft_p50_ms", ttft.p50() * 1e3)
                .num("ttft_p95_ms", ttft.p95() * 1e3)
                .num("score", score.mean())
                .num("req_per_s", completions.len() as f64 / wall)
                .num("tok_per_s", tokens_out as f64 / wall)
                .num("mean_batch", sched.stats.mean_occupancy()),
        );
    }
    emit("serve_mmdu_e2e", &[table]);
    println!("engine metrics: {}", engine.metrics.snapshot().encode());
    Ok(())
}
