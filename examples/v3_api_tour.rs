//! Tour of the v3 cache-plane API over live TCP, driven entirely through
//! the typed [`MpicClient`] SDK: tenant namespaces, the lease lifecycle
//! (grant → renew → release, with expiry), streaming decode through an
//! [`InferHandle`], and in-flight cancellation.
//!
//! ```sh
//! cargo run --release --example v3_api_tour
//! ```

use std::time::Duration;

use mpic::harness;
use mpic::server::{InferOutcome, InferParams, MpicClient};

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let engine = harness::experiment_engine("mpic-sim-a", "v3-tour")?;
    let (addr_tx, addr_rx) = std::sync::mpsc::channel();

    // The engine loop owns this thread (PJRT); the tour drives it from a
    // client thread, exactly like an external caller would.
    let tour = std::thread::spawn(move || -> mpic::Result<()> {
        let addr = addr_rx.recv().expect("server address");

        println!("== two tenants upload the same handle ==");
        let mut alice = MpicClient::connect(addr)?.with_namespace("tenant-alice")?;
        let mut bob = MpicClient::connect(addr)?.with_namespace("tenant-bob")?;
        let a_hex = alice.upload(7, "IMAGE#LOGO")?;
        let b_hex = bob.upload(7, "IMAGE#LOGO")?;
        println!("  alice's IMAGE#LOGO -> {a_hex}\n  bob's   IMAGE#LOGO -> {b_hex}");
        println!("  (same content hash, distinct cache entries — see cache.list below)");
        for (name, c) in [("alice", &mut alice), ("bob", &mut bob)] {
            let entries = c.cache_list()?;
            let plural = if entries.len() == 1 { "y" } else { "ies" };
            println!("  {name} sees {} entr{plural}", entries.len());
        }

        println!("== lease lifecycle: grant, refuse evict, renew, release ==");
        let lease = alice.lease("IMAGE#LOGO", Some(60_000))?;
        println!("  leased for 60s: lease id {}", lease.id);
        match alice.cache_evict("IMAGE#LOGO") {
            Err(e) => println!("  evict while leased: {e:#}"),
            Ok(()) => println!("  BUG: evict succeeded on a leased entry"),
        }
        let lease = alice.lease_renew(&lease, Some(120_000))?;
        println!("  renewed to 120s");
        alice.lease_release(&lease)?;
        println!("  released; evict now succeeds: {:?}", alice.cache_evict("IMAGE#LOGO"));

        println!("== streaming decode + mid-flight cancellation ==");
        bob.upload(7, "IMAGE#SKYLINE")?;
        let mut handle = bob.infer_stream(
            &InferParams::new(7, "Describe IMAGE#SKYLINE in detail please")
                .policy("mpic-16")
                .max_new(24),
        )?;
        let mut seen = 0usize;
        while let Some(chunk) = handle.recv_chunk()? {
            seen += 1;
            if chunk.seq == 2 {
                println!("  3 tokens in — cancelling");
                handle.cancel()?;
            }
        }
        match handle.join()? {
            InferOutcome::Cancelled { message } => {
                println!("  stream cancelled after {seen} chunks: {message}")
            }
            InferOutcome::Completed(r) => {
                let n = r.tokens.len();
                println!("  stream finished with {n} tokens (cancel raced completion)")
            }
        }

        println!("== the slot freed by the cancel serves the next request ==");
        let r = bob.infer(
            &InferParams::new(7, "Briefly describe IMAGE#SKYLINE").policy("mpic-16").max_new(2),
        )?;
        let (n, ttft_ms) = (r.tokens.len(), r.ttft_s * 1e3);
        println!("  {n} tokens, ttft {ttft_ms:.1} ms, device hits {}", r.device_hits);

        println!("== pipeline health (cancelled counter, lease stats) ==");
        let stats = bob.stats()?;
        let pipe = stats.get("metrics")?.get("pipeline")?;
        let kv = stats.get("metrics")?.get("kv")?;
        println!(
            "  cancelled={} leases_acquired={} leases_released={}",
            pipe.get("cancelled")?.as_f64()?,
            kv.get("leases_acquired")?.as_f64()?,
            kv.get("leases_released")?.as_f64()?,
        );

        // Give the engine loop a breath so the cancelled slot is reaped,
        // then stop the server.
        std::thread::sleep(Duration::from_millis(50));
        bob.shutdown()?;
        Ok(())
    });

    mpic::server::serve(&engine, "127.0.0.1:0", |a| {
        addr_tx.send(a).expect("publish address");
    })?;
    tour.join().expect("tour thread")?;
    println!("v3 API tour complete ✓");
    Ok(())
}
