//! The online serving pipeline from a client's point of view, driven
//! through the typed [`MpicClient`] SDK: three concurrent streaming
//! `infer`s interleaving their token chunks, the async upload lane
//! (`"async":true` + `upload.stat` polling, via the raw escape hatch),
//! and `overloaded` backpressure surfacing as a typed [`WireError`].
//!
//! ```sh
//! cargo run --release --example concurrent_clients
//! ```

use std::sync::mpsc::channel;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use mpic::harness;
use mpic::server::api::ErrorCode;
use mpic::server::client::WireError;
use mpic::server::pipeline::PipelineConfig;
use mpic::server::{InferParams, MpicClient, ServeConfig};
use mpic::util::json::Value;

fn req(s: &str) -> Value {
    Value::parse(s).expect("request literal")
}

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let engine = harness::experiment_engine("mpic-sim-a", "concurrent")?;
    let (addr_tx, addr_rx) = channel();

    let driver = std::thread::spawn(move || -> mpic::Result<()> {
        let addr = addr_rx.recv().expect("server address");
        let mut admin = MpicClient::connect(addr)?;

        println!("== async upload lane: accept now, precompute off the critical path ==");
        // The async lane is a raw-envelope feature; the typed client's
        // escape hatch carries it without giving up id verification.
        let acc = admin.call_raw(
            &req(r#"{"v":3,"id":"u1","op":"upload","user":1,"handle":"IMAGE#CITY","async":true}"#),
            |_| {},
        )?;
        println!("  accepted: {}", acc.encode());
        let job = acc.get("job")?.as_u64()?;
        loop {
            let stat_req = req(&format!(r#"{{"op":"upload.stat","job":{job}}}"#));
            let st = admin.call_raw(&stat_req, |_| {})?;
            let state = st.get("state")?.as_str()?.to_string();
            println!("  upload.stat -> {state}");
            if state == "done" || state == "failed" {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        println!("== three concurrent streaming infers: chunks interleave ==");
        let order: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let barrier = Arc::new(Barrier::new(3));
        let mut clients = Vec::new();
        for name in ["A", "B", "C"] {
            let order = Arc::clone(&order);
            let barrier = Arc::clone(&barrier);
            clients.push(std::thread::spawn(move || -> mpic::Result<()> {
                let mut c = MpicClient::connect(addr)?;
                barrier.wait();
                let mut h = c.infer_stream(
                    &InferParams::new(1, "Describe IMAGE#CITY in detail please")
                        .policy("mpic-16")
                        .max_new(6),
                )?;
                while let Some(chunk) = h.recv_chunk()? {
                    order.lock().unwrap().push(format!("{name}{}", chunk.seq));
                }
                h.join()?;
                Ok(())
            }));
        }
        for h in clients {
            h.join().expect("client thread")?;
        }
        println!("  chunk arrival order: {}", order.lock().unwrap().join(" "));

        println!("== backpressure: the in-flight bound rejects with `overloaded` ==");
        // This server runs with queue_bound=3: hold all three slots with
        // long streams, then watch a fourth request bounce.
        let hold = Arc::new(Barrier::new(4));
        let mut streams = Vec::new();
        for _ in 0..3 {
            let hold = Arc::clone(&hold);
            streams.push(std::thread::spawn(move || -> mpic::Result<()> {
                let mut c = MpicClient::connect(addr)?;
                let mut h = c.infer_stream(
                    &InferParams::new(1, "Describe IMAGE#CITY in detail please")
                        .policy("mpic-16")
                        .max_new(16),
                )?;
                let mut signalled = false;
                while let Some(_chunk) = h.recv_chunk()? {
                    if !signalled {
                        hold.wait();
                        signalled = true;
                    }
                }
                h.join()?;
                Ok(())
            }));
        }
        hold.wait(); // all three streams are mid-flight
        match admin.infer(&InferParams::new(1, "Describe IMAGE#CITY please")) {
            Err(e) => match e.downcast_ref::<WireError>() {
                Some(w) if w.code == ErrorCode::Overloaded => {
                    println!("  fourth request bounced: {w}")
                }
                _ => return Err(e),
            },
            Ok(_) => println!("  fourth request served (streams finished first)"),
        }
        for s in streams {
            s.join().expect("stream thread")?;
        }

        let stats = admin.stats()?;
        println!("== pipeline health == {}", stats.get("metrics")?.get("pipeline")?.encode());
        admin.shutdown()?;
        Ok(())
    });

    let cfg = ServeConfig {
        pipeline: PipelineConfig { queue_bound: 3, ..Default::default() },
        ..Default::default()
    };
    mpic::server::serve_with(&engine, "127.0.0.1:0", cfg, |a| {
        addr_tx.send(a).expect("address channel");
    })?;
    driver.join().expect("driver thread")?;
    Ok(())
}
