//! The online serving pipeline from a client's point of view: three
//! concurrent streaming `infer`s interleaving their token chunks, the
//! async upload lane (`"async":true` + `upload.stat` polling), and
//! `overloaded` backpressure when the in-flight bound is exceeded.
//!
//! ```sh
//! cargo run --release --example concurrent_clients
//! ```

use std::sync::mpsc::channel;
use std::sync::{Arc, Barrier, Mutex};
use std::time::Duration;

use mpic::harness;
use mpic::server::pipeline::PipelineConfig;
use mpic::server::{Client, ServeConfig};
use mpic::util::json::Value;

fn req(s: &str) -> Value {
    Value::parse(s).expect("request literal")
}

fn main() -> mpic::Result<()> {
    mpic::util::logging::init();
    if !harness::artifacts_ready() {
        return Ok(());
    }
    let engine = harness::experiment_engine("mpic-sim-a", "concurrent")?;
    let (addr_tx, addr_rx) = channel();

    let driver = std::thread::spawn(move || -> mpic::Result<()> {
        let addr = addr_rx.recv().expect("server address");
        let mut admin = Client::connect(addr)?;

        println!("== async upload lane: accept now, precompute off the critical path ==");
        let acc = admin.call(&req(
            r#"{"v":2,"id":"u1","op":"upload","user":1,"handle":"IMAGE#CITY","async":true}"#,
        ))?;
        println!("  accepted: {}", acc.encode());
        let job = acc.get("job")?.as_u64()?;
        loop {
            let st = admin.call(&req(&format!(r#"{{"op":"upload.stat","job":{job}}}"#)))?;
            let state = st.get("state")?.as_str()?.to_string();
            println!("  upload.stat -> {state}");
            if state == "done" || state == "failed" {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }

        println!("== three concurrent streaming infers: chunks interleave ==");
        let order: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
        let barrier = Arc::new(Barrier::new(3));
        let mut clients = Vec::new();
        for name in ["A", "B", "C"] {
            let order = Arc::clone(&order);
            let barrier = Arc::clone(&barrier);
            clients.push(std::thread::spawn(move || -> mpic::Result<()> {
                let mut c = Client::connect(addr)?;
                barrier.wait();
                let fin = c.call_stream(
                    &req(&format!(
                        r#"{{"v":2,"id":"{name}","op":"infer","user":1,"policy":"mpic-16","max_new":6,"stream":true,"text":"Describe IMAGE#CITY in detail please"}}"#
                    )),
                    |chunk| {
                        let seq = chunk.get("seq").unwrap().as_usize().unwrap();
                        order.lock().unwrap().push(format!("{name}{seq}"));
                    },
                )?;
                anyhow::ensure!(fin.get("ok")?.as_bool()?, "stream failed");
                Ok(())
            }));
        }
        for h in clients {
            h.join().expect("client thread")?;
        }
        println!("  chunk arrival order: {}", order.lock().unwrap().join(" "));

        println!("== backpressure: the in-flight bound rejects with `overloaded` ==");
        // This server runs with queue_bound=3: hold all three slots with
        // long streams, then watch a fourth request bounce.
        let hold = Arc::new(Barrier::new(4));
        let mut streams = Vec::new();
        for name in ["H1", "H2", "H3"] {
            let hold = Arc::clone(&hold);
            streams.push(std::thread::spawn(move || -> mpic::Result<()> {
                let mut c = Client::connect(addr)?;
                let mut signalled = false;
                c.call_stream(
                    &req(&format!(
                        r#"{{"id":"{name}","op":"infer","user":1,"policy":"mpic-16","max_new":16,"stream":true,"text":"Describe IMAGE#CITY in detail please"}}"#
                    )),
                    |_| {
                        if !signalled {
                            hold.wait();
                            signalled = true;
                        }
                    },
                )?;
                Ok(())
            }));
        }
        hold.wait(); // all three streams are mid-flight
        let bounced = admin.call(&req(
            r#"{"v":2,"id":"x","op":"infer","user":1,"text":"Describe IMAGE#CITY please"}"#,
        ))?;
        println!("  fourth request: {}", bounced.encode());
        for s in streams {
            s.join().expect("stream thread")?;
        }

        let stats = admin.call(&req(r#"{"v":2,"op":"stats"}"#))?;
        println!(
            "== pipeline health == {}",
            stats.get("metrics")?.get("pipeline")?.encode()
        );
        admin.call(&req(r#"{"op":"shutdown"}"#))?;
        Ok(())
    });

    let cfg = ServeConfig {
        pipeline: PipelineConfig { queue_bound: 3, ..Default::default() },
        ..Default::default()
    };
    mpic::server::serve_with(&engine, "127.0.0.1:0", cfg, |a| {
        addr_tx.send(a).expect("address channel");
    })?;
    driver.join().expect("driver thread")?;
    Ok(())
}
